//! L3 coordinator: the paper's system contribution.
//!
//! [`runner::EvalRunner`] drives the four pipeline stages of Figure 1;
//! [`compare`] adds the paired-model significance machinery; and
//! [`cached_engine::CachedEngine`] threads every LLM call (main inference,
//! judge, RAG verification) through the content-addressable cache so
//! replay mode covers the whole pipeline.

pub mod cached_engine;
pub mod compare;
pub mod pairwise;
pub mod plan_exec;
pub mod result;
pub mod runner;
pub mod stopping;
pub mod streaming;
pub mod worker;

pub use cached_engine::{CachedEngine, CallMeter, CallStats};
pub use compare::compare_results;
pub use pairwise::{PairVerdict, PairwiseResult};
pub use plan_exec::{PlanExecutor, PlanHost};
pub use result::{ComparisonResult, EvalResult, InferenceStats, MetricComparison, MetricValue};
pub use runner::{EvalRunner, RowInference, RunObserver};
pub use stopping::{MetricStopState, StoppingDriver};
pub use streaming::{StreamControl, StreamUpdate};
pub use worker::{serve_connection, serve_worker_main, worker_main};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CachePolicy, CiMethod, EvalTask, MetricConfig, StoppingConfig};
    use crate::data::synth;
    use crate::providers::simulated::SimServiceConfig;
    use crate::ratelimit::VirtualClock;

    fn fast_runner() -> EvalRunner {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig {
            server_error_rate: 0.0,
            unparseable_rate: 0.0,
            sleep_latency: false,
            ..Default::default()
        };
        r
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("slleval-coord-test")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn end_to_end_lexical_eval() {
        let runner = fast_runner();
        let df = synth::generate_default(200, 31);
        let mut task = EvalTask::default();
        task.metrics = vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("token_f1", "lexical"),
            MetricConfig::new("rouge_l", "lexical"),
        ];
        let result = runner.evaluate(&df, &task).unwrap();
        assert_eq!(result.metrics.len(), 3);
        for m in &result.metrics {
            assert_eq!(m.n + m.n_failed, 200);
            assert!(m.ci.lo <= m.value && m.value <= m.ci.hi, "{}: CI order", m.name);
        }
        // gpt-4o quality 0.9 → exact match in a plausible band.
        let em = result.metric("exact_match").unwrap();
        assert!((0.55..0.98).contains(&em.value), "em {}", em.value);
        assert_eq!(result.inference.examples, 200);
        assert!(result.inference.total_cost_usd > 0.0);
        assert!(result.inference.throughput_per_min > 0.0);
    }

    #[test]
    fn failed_examples_are_excluded_and_counted() {
        let mut runner = fast_runner();
        // Heavy fault injection, no retries so failures surface.
        runner.service_config.server_error_rate = 0.3;
        let df = synth::generate_default(150, 33);
        let mut task = EvalTask::default();
        task.inference.max_retries = 0;
        let result = runner.evaluate(&df, &task).unwrap();
        assert!(!result.failed_examples.is_empty(), "expected some failures");
        let em = result.metric("exact_match").unwrap();
        assert_eq!(em.n_failed, result.failed_examples.len());
        assert_eq!(em.n + em.n_failed, 150);
        assert_eq!(result.inference.failed as usize, result.failed_examples.len());
    }

    #[test]
    fn retries_recover_transient_errors() {
        let mut runner = fast_runner();
        runner.service_config.server_error_rate = 0.3;
        let df = synth::generate_default(100, 34);
        let mut task = EvalTask::default();
        task.inference.max_retries = 5;
        let result = runner.evaluate(&df, &task).unwrap();
        assert!(result.failed_examples.is_empty(), "retries should recover");
        assert!(result.inference.retries > 0);
    }

    #[test]
    fn cache_round_trip_and_replay() {
        let dir = tmp_dir("replay-e2e");
        let df = synth::generate_default(80, 35);
        let mut task = EvalTask::default();
        task.inference.cache_policy = CachePolicy::Enabled;

        // Initial run populates the cache.
        let mut runner = fast_runner();
        runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
        let r1 = runner.evaluate(&df, &task).unwrap();
        // (Duplicate prompts inside the dataset may hit the warming cache;
        // what matters is that the run paid for real API calls.)
        assert!(r1.inference.api_calls > 0);
        assert!(r1.inference.total_cost_usd > 0.0);

        // Replay run: zero API calls, zero cost, same responses.
        let mut runner2 = fast_runner();
        runner2.open_cache(&dir, CachePolicy::Replay).unwrap();
        let mut task2 = task.clone();
        task2.inference.cache_policy = CachePolicy::Replay;
        task2.metrics.push(MetricConfig::new("bleu", "lexical")); // metric iteration
        let r2 = runner2.evaluate(&df, &task2).unwrap();
        assert_eq!(r2.inference.cache_hits as usize, df.len());
        assert_eq!(r2.inference.api_calls, 0);
        assert_eq!(r2.inference.total_cost_usd, 0.0);
        // Identical metric values on the replayed responses.
        let em1 = r1.metric("exact_match").unwrap().value;
        let em2 = r2.metric("exact_match").unwrap().value;
        assert!((em1 - em2).abs() < 1e-12);
    }

    #[test]
    fn replay_on_cold_cache_fails() {
        let dir = tmp_dir("replay-cold");
        let mut runner = fast_runner();
        runner.open_cache(&dir, CachePolicy::Replay).unwrap();
        let mut task = EvalTask::default();
        task.inference.cache_policy = CachePolicy::Replay;
        let df = synth::generate_default(10, 36);
        assert!(runner.evaluate(&df, &task).is_err());
    }

    #[test]
    fn judge_metric_end_to_end() {
        let runner = fast_runner();
        let df = synth::generate_default(60, 37);
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("helpfulness", "llm_judge")
            .with_param("rubric", crate::util::json::Json::str("Rate helpfulness 1-5"))];
        let result = runner.evaluate(&df, &task).unwrap();
        let j = result.metric("helpfulness").unwrap();
        assert!(j.n > 0);
        assert!((1.0..=5.0).contains(&j.value), "judge mean {}", j.value);
        // The scale-misclassification fix: a plainly named judge metric
        // carries Ordinal scale (it used to fall back to Complex and get
        // the wrong significance machinery).
        assert_eq!(
            result.report("helpfulness").unwrap().scale,
            crate::stats::MetricScale::Ordinal
        );
        // Judge traffic is accounted, not assumed: 60 billed calls.
        assert_eq!(result.metric_calls.api_calls, 60);
        assert!(result.metric_calls.cost_usd > 0.0);
        assert_eq!(result.metric_calls.cache_hits, 0);
    }

    #[test]
    fn unknown_metric_name_fails_at_load_time() {
        // Resolution happens before stage 1: no inference is paid for a
        // typo'd metric. ("custom" family defers to the runner registry,
        // so an unregistered custom name must fail in evaluate.)
        let df = synth::generate_default(10, 42);
        let mut task = EvalTask::default();
        task.metrics = vec![MetricConfig::new("not_registered", "custom")];
        let err = fast_runner().evaluate(&df, &task).unwrap_err();
        assert!(format!("{err}").contains("unknown metric"), "{err}");
    }

    #[test]
    fn rag_metrics_end_to_end() {
        let runner = fast_runner();
        let df = synth::generate(
            60,
            38,
            synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();
        let mut task = EvalTask::default();
        task.metrics = vec![
            MetricConfig::new("context_precision", "rag"),
            MetricConfig::new("context_recall", "rag"),
            MetricConfig::new("faithfulness", "rag"),
        ];
        let result = runner.evaluate(&df, &task).unwrap();
        for name in ["context_precision", "context_recall", "faithfulness"] {
            let m = result.metric(name).unwrap();
            assert!(m.n > 0, "{name} scored nothing");
            assert!((0.0..=1.0).contains(&m.value), "{name} = {}", m.value);
        }
        // Gold chunks exist → recall should be perfect by construction.
        assert!((result.metric("context_recall").unwrap().value - 1.0).abs() < 1e-9);
    }

    #[test]
    fn analytic_ci_for_binary_uses_wilson() {
        let runner = fast_runner();
        let df = synth::generate_default(100, 39);
        let mut task = EvalTask::default();
        task.statistics.ci_method = crate::config::CiMethod::Analytic;
        let result = runner.evaluate(&df, &task).unwrap();
        assert_eq!(result.metric("exact_match").unwrap().ci.method, "wilson");
    }

    #[test]
    fn deterministic_across_runs() {
        let df = synth::generate_default(80, 40);
        let task = EvalTask::default();
        let r1 = fast_runner().evaluate(&df, &task).unwrap();
        let r2 = fast_runner().evaluate(&df, &task).unwrap();
        assert_eq!(
            r1.metric("exact_match").unwrap().value,
            r2.metric("exact_match").unwrap().value
        );
    }

    /// Checkpoint-friendly task: cache disabled (so provider-call counts
    /// are not masked by cache hits), no speculation/splitting (so each
    /// row costs exactly one API call), small batches (so aborts land
    /// mid-flight).
    fn durable_task() -> EvalTask {
        let mut task = EvalTask::default();
        task.inference.cache_policy = CachePolicy::Disabled;
        task.inference.batch_size = 5;
        task.scheduler.speculation = false;
        task.scheduler.adaptive_split = false;
        task
    }

    #[test]
    fn interrupted_run_resumes_byte_identical_without_repaying_completed_work() {
        let n = 200;
        let df = synth::generate_default(n, 55);

        // Reference: one uninterrupted run.
        let full = fast_runner().evaluate(&df, &durable_task()).unwrap();
        assert_eq!(full.inference.api_calls, n as u64, "1 call per row in this setup");
        assert!(full.inference.total_cost_usd > 0.0);

        // Interrupted run: a provider-spend budget of ~40% of the full
        // cost aborts the job mid-flight; completed tasks are spilled to
        // the checkpoint directory as they win.
        let dir = tmp_dir("resume-interrupt");
        let mut task = durable_task();
        task.inference.max_cost_usd = Some(0.4 * full.inference.total_cost_usd);
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let err = runner.evaluate(&df, &task).unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");

        // Resumed run: restore the manifest, execute only the gaps.
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        let resumed = runner.evaluate(&df, &durable_task()).unwrap();

        let restored = resumed.inference.sched.restored_rows;
        assert!(restored > 0, "the interrupted run must have completed some tasks");
        assert!(restored < n, "the interrupted run must not have finished");
        // Completed ranges are never re-executed: every fresh row costs
        // exactly one provider call, and restored rows cost zero.
        assert_eq!(resumed.inference.api_calls, (n - restored) as u64);
        assert_eq!(resumed.inference.examples, n);

        // The stitched-together report is identical to the uninterrupted
        // run: same per-row metric values, same aggregates, same CIs.
        assert_eq!(resumed.reports[0].values, full.reports[0].values);
        let (m_full, m_res) = (
            full.metric("exact_match").unwrap(),
            resumed.metric("exact_match").unwrap(),
        );
        assert_eq!(m_full.value, m_res.value);
        assert_eq!((m_full.ci.lo, m_full.ci.hi), (m_res.ci.lo, m_res.ci.hi));
        assert_eq!(m_full.n, m_res.n);
    }

    #[test]
    fn resuming_a_completed_run_restores_everything_with_zero_calls() {
        let n = 120;
        let df = synth::generate_default(n, 56);
        let dir = tmp_dir("resume-complete");

        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let r1 = runner.evaluate(&df, &durable_task()).unwrap();
        assert_eq!(r1.inference.api_calls, n as u64);

        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        let r2 = runner.evaluate(&df, &durable_task()).unwrap();
        assert_eq!(r2.inference.api_calls, 0, "everything restored, nothing re-paid");
        assert_eq!(r2.inference.total_cost_usd, 0.0);
        assert_eq!(r2.inference.sched.restored_rows, n);
        // Per-row accounting is fresh-only: restored rows must not be
        // re-reported as this run's cache misses or latencies.
        assert_eq!(r2.inference.cache_misses, 0);
        assert_eq!(r2.inference.latency_p50_ms, 0.0);
        assert_eq!(r2.reports[0].values, r1.reports[0].values);
        assert_eq!(
            r1.metric("exact_match").unwrap().value,
            r2.metric("exact_match").unwrap().value
        );
    }

    #[test]
    fn fresh_checkpoint_refuses_an_occupied_run_dir() {
        let df = synth::generate_default(30, 57);
        let dir = tmp_dir("resume-occupied");
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        runner.evaluate(&df, &durable_task()).unwrap();

        let mut runner = fast_runner();
        assert!(
            runner.attach_checkpoint(&dir, false).is_err(),
            "starting a fresh run over an existing one must be explicit (--resume)"
        );
    }

    #[test]
    fn resume_with_changed_inputs_reexecutes_instead_of_mixing() {
        // The stage is content-addressed on the prompts: resuming against
        // a different dataset silently re-executes everything rather than
        // stitching mismatched rows.
        let dir = tmp_dir("resume-changed");
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        runner.evaluate(&synth::generate_default(40, 58), &durable_task()).unwrap();

        let other = synth::generate_default(40, 59); // different seed
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        let r = runner.evaluate(&other, &durable_task()).unwrap();
        assert_eq!(r.inference.sched.restored_rows, 0);
        assert_eq!(r.inference.api_calls, 40);
    }

    // ---------------------------------------------------- adaptive stopping

    /// Stopping setup sized so the simulated model certifies within a
    /// wave or two at a loose ±0.15 target: even at the worst-case
    /// observed rate (p̂ = 0.5) the wave geometry stays well clear of the
    /// alpha-spending asymptote, so the test can never hang on an
    /// unreachable target. Analytic CIs keep the certification check
    /// closed-form (Wilson for the binary default metric).
    fn stopping_task() -> EvalTask {
        let mut task = durable_task();
        task.statistics.ci_method = CiMethod::Analytic;
        task.stopping = Some(StoppingConfig {
            ci_half_width: 0.15,
            alpha: 0.05,
            wave_size: 60,
            min_rows: 60,
            spend_alpha: true,
        });
        task
    }

    #[test]
    fn stopping_disabled_stays_bit_identical_and_unmarked() {
        use crate::util::json::Json;
        let df = synth::generate_default(120, 71);
        let task = durable_task();
        assert!(task.stopping.is_none());
        let r1 = fast_runner().evaluate(&df, &task).unwrap();
        let r2 = fast_runner().evaluate(&df, &task).unwrap();
        // Metrics, CIs, and cost are pinned bit-identical: the
        // wave-capable scheduler path with no gate must not perturb
        // anything about an ordinary run.
        let m1 = Json::arr(r1.metrics.iter().map(|m| m.to_json()).collect()).to_string();
        let m2 = Json::arr(r2.metrics.iter().map(|m| m.to_json()).collect()).to_string();
        assert_eq!(m1, m2);
        assert_eq!(r1.inference.api_calls, r2.inference.api_calls);
        assert_eq!(r1.inference.total_cost_usd, r2.inference.total_cost_usd);
        // No stopping vocabulary leaks into a disabled run's result JSON.
        let j = r1.to_json().to_string();
        for key in ["stopped_at_wave", "certified"] {
            assert!(!j.contains(key), "{key} must not appear in a disabled run");
        }
        let s = &r1.inference.sched;
        assert_eq!((s.waves, s.rows_saved, s.rows_evaluated), (0, 0, 120));
    }

    #[test]
    fn stopping_certifies_early_and_accounts_every_row() {
        let n = 600;
        let df = synth::generate_default(n, 72);
        let task = stopping_task();
        let r = fast_runner().evaluate(&df, &task).unwrap();
        let s = &r.inference.sched;
        assert!(s.rows_saved > 0, "loose target must settle before the frame ends");
        assert_eq!(s.rows_evaluated + s.rows_saved, n, "every row is evaluated or saved");
        assert!(2 * s.rows_saved >= n, "a ±0.15 target should save at least half");
        assert!(s.waves >= 1);
        // Inference only ever ran on the evaluated prefix: 1 call per row
        // in the durable setup, and examples counts evaluated rows.
        assert_eq!(r.inference.api_calls, s.rows_evaluated as u64);
        assert_eq!(r.inference.examples, s.rows_evaluated);
        let em = r.metric("exact_match").unwrap();
        assert_eq!(em.certified, Some(true));
        assert!(em.stopped_at_wave.is_some());
        assert_eq!(em.n + em.n_failed, s.rows_evaluated);
        // The final (full-level) CI meets the certified target too.
        assert!((em.ci.hi - em.ci.lo) / 2.0 <= 0.15, "certified half-width holds");
    }

    #[test]
    fn unreachable_target_evaluates_the_whole_frame_uncertified() {
        let n = 120;
        let df = synth::generate_default(n, 73);
        let mut base = durable_task();
        base.statistics.ci_method = CiMethod::Analytic;
        let disabled = fast_runner().evaluate(&df, &base).unwrap();

        let mut task = stopping_task();
        task.stopping.as_mut().unwrap().ci_half_width = 1e-6;
        let r = fast_runner().evaluate(&df, &task).unwrap();
        let s = &r.inference.sched;
        assert_eq!((s.rows_evaluated, s.rows_saved), (n, 0));
        assert!(s.waves >= 1, "the gate looked at least once");
        let em = r.metric("exact_match").unwrap();
        assert_eq!(em.certified, Some(false));
        assert_eq!(em.stopped_at_wave, None);
        // The wave loop only changes *when* inference stops, never what a
        // row contributes: exhausting the frame matches the disabled run.
        let d = disabled.metric("exact_match").unwrap();
        assert_eq!(em.value, d.value);
        assert_eq!((em.ci.lo, em.ci.hi), (d.ci.lo, d.ci.hi));
        assert_eq!(em.n, d.n);
    }

    #[test]
    fn stopping_run_killed_mid_wave_resumes_without_reinference_and_same_certification() {
        let n = 600;
        let df = synth::generate_default(n, 74);
        let task = stopping_task();

        // Reference: one uninterrupted stopping run.
        let full = fast_runner().evaluate(&df, &task).unwrap();
        let evaluated = full.inference.sched.rows_evaluated;
        assert!(evaluated < n, "reference run must stop early");

        // Kill mid-wave: a provider-spend budget aborts the job with part
        // of the first wave complete and spilled to the checkpoint.
        let dir = tmp_dir("stopping-resume");
        let mut aborted = task.clone();
        aborted.inference.max_cost_usd = Some(0.4 * full.inference.total_cost_usd);
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let err = runner.evaluate(&df, &aborted).unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");

        // Resume: restored rows are never re-inferred, and the wave loop
        // replays to the identical certification decision.
        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        let resumed = runner.evaluate(&df, &task).unwrap();
        let restored = resumed.inference.sched.restored_rows;
        assert!(restored > 0, "the killed run must have banked some rows");
        assert_eq!(resumed.inference.api_calls, (evaluated - restored) as u64);
        assert_eq!(resumed.inference.sched.rows_evaluated, evaluated);
        assert_eq!(resumed.inference.sched.rows_saved, n - evaluated);
        let (a, b) = (
            full.metric("exact_match").unwrap(),
            resumed.metric("exact_match").unwrap(),
        );
        assert_eq!(a.value, b.value);
        assert_eq!((a.ci.lo, a.ci.hi), (b.ci.lo, b.ci.hi));
        assert_eq!(a.stopped_at_wave, b.stopped_at_wave);
        assert_eq!(a.certified, b.certified);
    }

    #[test]
    fn rescore_on_a_stopped_run_scores_only_evaluated_rows() {
        let n = 600;
        let dir = tmp_dir("stopping-rescore");
        let df = synth::generate_default(n, 75);
        let task = stopping_task();

        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let live = runner.evaluate(&df, &task).unwrap();
        let evaluated = live.inference.sched.rows_evaluated;
        assert!(evaluated < n);

        // Rescore with an extra pure metric: the deliberately-saved
        // suffix is not missing work — no per-row errors, no provider
        // calls, and every metric scores exactly the evaluated prefix.
        let mut task2 = task.clone();
        task2.metrics.push(MetricConfig::new("token_f1", "lexical"));
        let mut runner2 = fast_runner();
        runner2.attach_checkpoint(&dir, true).unwrap();
        let re = runner2.rescore(&df, &task2, false).unwrap();
        assert_eq!(re.inference.api_calls, 0);
        assert_eq!(re.inference.sched.restored_rows, evaluated);
        let em = re.metric("exact_match").unwrap();
        assert_eq!(em.n + em.n_failed, evaluated);
        assert_eq!(live.metric("exact_match").unwrap().value, em.value);
        assert_eq!(re.metric("token_f1").unwrap().n, evaluated);
    }

    // ------------------------------------------------------------- rescore

    #[test]
    fn rescore_from_cache_is_free_and_bit_identical() {
        let dir = tmp_dir("rescore-cache");
        let df = synth::generate_default(80, 61);
        let mut task = EvalTask::default();
        task.metrics = vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("helpfulness", "llm_judge")
                .with_param("rubric", crate::util::json::Json::str("Rate helpfulness 1-5")),
        ];

        // Live run: pays for inference + judge calls, populates the cache.
        let mut runner = fast_runner();
        runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
        let live = runner.evaluate(&df, &task).unwrap();
        assert!(live.inference.api_calls > 0);
        // One judge call per example; duplicates may hit the warming cache.
        assert!(live.metric_calls.api_calls > 0);
        assert_eq!(live.metric_calls.api_calls + live.metric_calls.cache_hits, 80);

        // Rescore under a strict replay cache: add a new pure metric —
        // zero API calls anywhere, and the unchanged metrics come back
        // bit-identical (values AND bootstrap CIs: same seed, same data).
        let mut task2 = task.clone();
        task2.metrics.push(MetricConfig::new("rouge_l", "lexical"));
        let mut runner2 = fast_runner();
        runner2.open_cache(&dir, CachePolicy::Replay).unwrap();
        let re = runner2.rescore(&df, &task2, false).unwrap();
        assert_eq!(re.inference.api_calls, 0);
        assert_eq!(re.inference.total_cost_usd, 0.0);
        assert_eq!(re.inference.cache_hits as usize, df.len());
        assert_eq!(re.metric_calls.api_calls, 0, "judge calls must replay from cache");
        assert_eq!(re.metric_calls.cache_hits, 80);
        assert_eq!(re.inference.failed, 0);

        for name in ["exact_match", "helpfulness"] {
            let (a, b) = (live.report(name).unwrap(), re.report(name).unwrap());
            assert_eq!(a.values, b.values, "{name} per-row values");
            assert_eq!(a.scale, b.scale);
            let (ma, mb) = (live.metric(name).unwrap(), re.metric(name).unwrap());
            assert_eq!(ma.value, mb.value, "{name} point estimate");
            assert_eq!((ma.ci.lo, ma.ci.hi), (mb.ci.lo, mb.ci.hi), "{name} CI");
        }
        // The new metric scored the full frame without inference.
        assert_eq!(re.metric("rouge_l").unwrap().n, 80);
    }

    #[test]
    fn rescore_from_checkpoint_needs_no_cache() {
        let n = 60;
        let dir = tmp_dir("rescore-ckpt");
        let df = synth::generate_default(n, 62);
        // Cache disabled: the checkpoint is the only response source.
        let task = durable_task();

        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let live = runner.evaluate(&df, &task).unwrap();
        assert_eq!(live.inference.api_calls, n as u64);

        let mut runner2 = fast_runner();
        runner2.attach_checkpoint(&dir, true).unwrap();
        let mut task2 = task.clone();
        task2.metrics.push(MetricConfig::new("token_f1", "lexical"));
        let re = runner2.rescore(&df, &task2, false).unwrap();
        assert_eq!(re.inference.api_calls, 0);
        assert_eq!(re.inference.sched.restored_rows, n);
        assert_eq!(re.inference.cache_hits, 0, "no cache lookups when restored");
        assert_eq!(re.reports[0].values, live.reports[0].values);
        assert_eq!(
            live.metric("exact_match").unwrap().value,
            re.metric("exact_match").unwrap().value
        );
        assert_eq!(re.metric("token_f1").unwrap().n, n);
    }

    #[test]
    fn rescore_without_sources_fails_unless_missing_allowed() {
        let df = synth::generate_default(12, 63);
        let task = EvalTask::default();
        // No cache, no checkpoint: a clear error naming both sources.
        let err = fast_runner().rescore(&df, &task, false).unwrap_err();
        assert!(format!("{err:#}").contains("response source"), "{err:#}");

        // Cold cache, strict: per-row miss error.
        let dir = tmp_dir("rescore-cold");
        let mut runner = fast_runner();
        runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
        let err = runner.rescore(&df, &task, false).unwrap_err();
        assert!(format!("{err:#}").contains("rescore"), "{err:#}");

        // Cold cache, --allow-missing: every row scores as failed.
        let re = runner.rescore(&df, &task, true).unwrap();
        assert_eq!(re.inference.failed, 12);
        assert_eq!(re.failed_examples.len(), 12);
        assert_eq!(re.metric("exact_match").unwrap().n, 0);
    }

    #[test]
    fn custom_metric_through_evaluate_and_rescore() {
        use crate::metrics::{Metric, MetricContext, MetricRequirements, ScoreBatch};
        use crate::stats::MetricScale;

        struct ResponseWords;
        impl Metric for ResponseWords {
            fn name(&self) -> &str {
                "response_words"
            }
            fn scale(&self) -> MetricScale {
                MetricScale::Continuous
            }
            fn requirements(&self) -> MetricRequirements {
                MetricRequirements::Pure
            }
            fn score_batch(
                &self,
                _ctx: &MetricContext<'_>,
                examples: &[crate::metrics::Example],
            ) -> anyhow::Result<ScoreBatch> {
                Ok(ScoreBatch::scored(
                    examples
                        .iter()
                        .map(|ex| Some(ex.response.split_whitespace().count() as f64))
                        .collect(),
                ))
            }
        }

        let dir = tmp_dir("rescore-custom");
        let df = synth::generate_default(50, 64);
        let mut task = EvalTask::default();
        task.metrics = vec![
            MetricConfig::new("exact_match", "lexical"),
            MetricConfig::new("response_words", "custom"),
        ];

        let mut runner = fast_runner();
        runner.registry.register_metric("custom", std::sync::Arc::new(ResponseWords));
        runner.open_cache(&dir, CachePolicy::Enabled).unwrap();
        let live = runner.evaluate(&df, &task).unwrap();
        let lw = live.metric("response_words").unwrap();
        assert_eq!(lw.n, 50);
        assert!(lw.value > 0.0);

        // Fresh runner, same registration: rescore from cache produces an
        // identical custom-metric report (values and CI).
        let mut runner2 = fast_runner();
        runner2.registry.register_metric("custom", std::sync::Arc::new(ResponseWords));
        runner2.open_cache(&dir, CachePolicy::Replay).unwrap();
        let re = runner2.rescore(&df, &task, false).unwrap();
        assert_eq!(re.inference.api_calls, 0);
        assert_eq!(
            live.report("response_words").unwrap().values,
            re.report("response_words").unwrap().values
        );
        let (ma, mb) =
            (live.metric("response_words").unwrap(), re.metric("response_words").unwrap());
        assert_eq!(ma.value, mb.value);
        assert_eq!((ma.ci.lo, ma.ci.hi), (mb.ci.lo, mb.ci.hi));
    }
}
