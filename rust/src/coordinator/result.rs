//! Result types produced by the evaluation runner.

use super::cached_engine::CallStats;
use crate::engine::ExecutorStats;
use crate::metrics::MetricReport;
use crate::sched::{SchedulerStats, TaskRecord};
use crate::stats::{ConfidenceInterval, EffectSize, TestChoice, TestResult};
use crate::util::json::Json;

/// An aggregated metric with its confidence interval (the paper's
/// `MetricValue(value=0.234, ci=(0.218, 0.251), n=10000)`).
#[derive(Debug, Clone)]
pub struct MetricValue {
    pub name: String,
    pub value: f64,
    pub ci: ConfidenceInterval,
    /// Examples actually scored.
    pub n: usize,
    /// Examples the metric could not score.
    pub n_failed: usize,
    /// Unparseable judge responses among the failures.
    pub unparseable: usize,
    /// Adaptive stopping: the 0-based wave at which this metric's CI
    /// certified (`None` = stopping disabled, or never certified).
    pub stopped_at_wave: Option<usize>,
    /// Adaptive stopping: whether the CI half-width met the target under
    /// the sequential correction (`None` = stopping disabled).
    pub certified: Option<bool>,
}

impl MetricValue {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("value", Json::num(self.value)),
            ("ci_lower", Json::num(self.ci.lo)),
            ("ci_upper", Json::num(self.ci.hi)),
            ("ci_method", Json::str(self.ci.method)),
            ("confidence_level", Json::num(self.ci.level)),
            ("n", Json::num(self.n as f64)),
            ("n_failed", Json::num(self.n_failed as f64)),
            ("unparseable", Json::num(self.unparseable as f64)),
        ];
        // Emitted only on stopping-enabled runs, so disabled result JSON
        // stays byte-identical to the pre-stopping format.
        if self.certified.is_some() || self.stopped_at_wave.is_some() {
            fields.push((
                "stopped_at_wave",
                self.stopped_at_wave.map(|w| Json::num(w as f64)).unwrap_or(Json::Null),
            ));
            fields.push(("certified", Json::Bool(self.certified.unwrap_or(false))));
        }
        Json::obj(fields)
    }
}

impl std::fmt::Display for MetricValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MetricValue(value={:.3}, ci=({:.3}, {:.3}), n={})",
            self.value, self.ci.lo, self.ci.hi, self.n
        )
    }
}

/// Inference-stage accounting.
#[derive(Debug, Clone, Default)]
pub struct InferenceStats {
    pub examples: usize,
    pub api_calls: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub retries: u64,
    pub failed: u64,
    pub total_cost_usd: f64,
    /// Wall time of the inference stage, seconds.
    pub wall_secs: f64,
    /// Observed latencies (ms) of actual API calls for percentile reports.
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    /// Examples per minute over the inference stage.
    pub throughput_per_min: f64,
    /// Task-scheduler telemetry for the inference stage (stealing,
    /// speculation, retries, skew).
    pub sched: SchedulerStats,
    /// Per-task-attempt timeline of the inference stage.
    pub timeline: Vec<TaskRecord>,
    /// Configured in-executor concurrency (`inference.concurrency`).
    pub concurrency: usize,
    /// Peak simultaneously in-flight provider requests observed across
    /// all executors' pipelines (≤ `concurrency` per executor).
    pub peak_in_flight: usize,
    /// Per-executor occupancy telemetry: rows, batches, wall-clock busy
    /// time (pipeline occupancy, never summed latency), peak in-flight.
    pub executors: Vec<ExecutorStats>,
}

impl InferenceStats {
    /// The `"inference"` object of the result JSON, also served on its
    /// own by the eval service as the stage-2 snapshot in
    /// `GET /runs/{id}` (scheduler telemetry is a sibling key there and
    /// in [`EvalResult::to_json`], so it is not nested here).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("examples", Json::num(self.examples as f64)),
            ("api_calls", Json::num(self.api_calls as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("total_cost_usd", Json::num(self.total_cost_usd)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("latency_p50_ms", Json::num(self.latency_p50_ms)),
            ("latency_p99_ms", Json::num(self.latency_p99_ms)),
            ("throughput_per_min", Json::num(self.throughput_per_min)),
            ("concurrency", Json::num(self.concurrency as f64)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            (
                "executors",
                Json::arr(
                    self.executors
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("executor_id", Json::num(e.executor_id as f64)),
                                ("rows_processed", Json::num(e.rows_processed as f64)),
                                ("batches", Json::num(e.batches as f64)),
                                ("busy_secs", Json::num(e.busy_secs)),
                                ("peak_in_flight", Json::num(e.peak_in_flight as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Complete evaluation outcome.
#[derive(Debug)]
pub struct EvalResult {
    pub task_id: String,
    pub provider: String,
    pub model: String,
    pub metrics: Vec<MetricValue>,
    pub reports: Vec<MetricReport>,
    pub inference: InferenceStats,
    /// Metric-stage call traffic (judge / RAG verification calls): billed
    /// provider calls, cache hits, failures, spend. Inference-stage calls
    /// are accounted separately in [`InferenceStats`].
    pub metric_calls: CallStats,
    /// Indices of examples whose inference failed non-recoverably.
    pub failed_examples: Vec<usize>,
    /// Total wall time of all four stages, seconds.
    pub wall_secs: f64,
}

impl EvalResult {
    pub fn metric(&self, name: &str) -> Option<&MetricValue> {
        self.metrics.iter().find(|m| m.name == name)
    }

    pub fn report(&self, name: &str) -> Option<&MetricReport> {
        self.reports.iter().find(|r| r.name == name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task_id", Json::str(&self.task_id)),
            ("provider", Json::str(&self.provider)),
            ("model", Json::str(&self.model)),
            ("metrics", Json::arr(self.metrics.iter().map(|m| m.to_json()).collect())),
            ("inference", self.inference.to_json()),
            (
                "metric_calls",
                Json::obj(vec![
                    ("api_calls", Json::num(self.metric_calls.api_calls as f64)),
                    ("cache_hits", Json::num(self.metric_calls.cache_hits as f64)),
                    ("failed", Json::num(self.metric_calls.failed as f64)),
                    ("cost_usd", Json::num(self.metric_calls.cost_usd)),
                ]),
            ),
            ("scheduler", self.inference.sched.to_json()),
            (
                "task_timeline",
                Json::arr(self.inference.timeline.iter().map(|t| t.to_json()).collect()),
            ),
            ("failed_examples", Json::num(self.failed_examples.len() as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
        ])
    }
}

/// One metric's comparison between two models (paper §4.3–§4.4).
#[derive(Debug, Clone)]
pub struct MetricComparison {
    pub metric: String,
    pub value_a: f64,
    pub value_b: f64,
    pub test_choice: TestChoice,
    pub test: TestResult,
    pub cohens_d: EffectSize,
    pub hedges_g: EffectSize,
    /// Odds ratio for binary metrics.
    pub odds_ratio: Option<EffectSize>,
    pub n: usize,
}

impl MetricComparison {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("metric", Json::str(&self.metric)),
            ("value_a", Json::num(self.value_a)),
            ("value_b", Json::num(self.value_b)),
            ("test", Json::str(self.test.test)),
            ("statistic", Json::num(self.test.statistic)),
            ("p_value", Json::num(self.test.p_value)),
            ("cohens_d", Json::num(self.cohens_d.value)),
            ("hedges_g", Json::num(self.hedges_g.value)),
            (
                "odds_ratio",
                self.odds_ratio.map(|o| Json::num(o.value)).unwrap_or(Json::Null),
            ),
            ("n", Json::num(self.n as f64)),
        ])
    }
}

/// Full two-model comparison.
#[derive(Debug)]
pub struct ComparisonResult {
    pub model_a: String,
    pub model_b: String,
    pub comparisons: Vec<MetricComparison>,
    pub alpha: f64,
}

impl ComparisonResult {
    pub fn significant(&self) -> Vec<&MetricComparison> {
        self.comparisons.iter().filter(|c| c.test.significant(self.alpha)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::ConfidenceInterval;

    #[test]
    fn metric_value_display_matches_paper_format() {
        let mv = MetricValue {
            name: "exact_match".into(),
            value: 0.234,
            ci: ConfidenceInterval { point: 0.234, lo: 0.218, hi: 0.251, level: 0.95, method: "bca" },
            n: 10_000,
            n_failed: 0,
            unparseable: 0,
            stopped_at_wave: None,
            certified: None,
        };
        assert_eq!(mv.to_string(), "MetricValue(value=0.234, ci=(0.218, 0.251), n=10000)");
    }

    #[test]
    fn json_shape() {
        let mv = MetricValue {
            name: "m".into(),
            value: 0.5,
            ci: ConfidenceInterval { point: 0.5, lo: 0.4, hi: 0.6, level: 0.95, method: "wilson" },
            n: 100,
            n_failed: 2,
            unparseable: 1,
            stopped_at_wave: None,
            certified: None,
        };
        let j = mv.to_json();
        assert_eq!(j.get("ci_lower").unwrap().as_f64().unwrap(), 0.4);
        assert_eq!(j.get("unparseable").unwrap().as_f64().unwrap(), 1.0);
        // Stopping fields only exist on stopping-enabled runs.
        assert!(j.get("certified").is_none());
        assert!(j.get("stopped_at_wave").is_none());
    }

    #[test]
    fn json_stopping_fields_appear_when_certified() {
        let mut mv = MetricValue {
            name: "m".into(),
            value: 0.5,
            ci: ConfidenceInterval { point: 0.5, lo: 0.4, hi: 0.6, level: 0.95, method: "wilson" },
            n: 100,
            n_failed: 0,
            unparseable: 0,
            stopped_at_wave: Some(3),
            certified: Some(true),
        };
        let j = mv.to_json();
        assert_eq!(j.get("stopped_at_wave").unwrap().as_f64().unwrap(), 3.0);
        assert!(matches!(j.get("certified"), Some(Json::Bool(true))));
        // A stopping-enabled run that exhausted the frame uncertified
        // still reports the state (wave is null).
        mv.stopped_at_wave = None;
        mv.certified = Some(false);
        let j = mv.to_json();
        assert!(matches!(j.get("certified"), Some(Json::Bool(false))));
        assert!(matches!(j.get("stopped_at_wave"), Some(Json::Null)));
    }
}
