//! Pairwise LLM-judge comparison (paper §4.1 "Pairwise Comparison") with
//! **position debiasing** — the paper's §6.1 limitation ("position bias:
//! preferring responses presented first"), addressed here by judging each
//! pair in both orders and keeping only consistent verdicts.

use std::sync::Mutex;

use super::cached_engine::CachedEngine;
use super::runner::EvalRunner;
use crate::config::{BackendKind, EvalTask, ModelConfig, StoppingConfig};
use crate::data::DataFrame;
use crate::metrics::judge::{pairwise_prompt, parse_verdict};
use crate::providers::pipeline::PipelinedClient;
use crate::providers::retry::RetryPolicy;
use crate::providers::simulated::SimEngine;
use crate::providers::{InferenceEngine, InferenceRequest};
use crate::sched::plan::{PairInput, PairwisePlan, PlanWork, StagePlan, TaskPlan};
use crate::sched::{
    run_scheduled_wave, TaskCheckpoint, TaskSink, WaveDecision, WaveGate,
};
use crate::stats::special::binom_test_half;
use crate::stats::tests::mcnemar_test;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Verdict for one example pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PairVerdict {
    AWins,
    BWins,
    /// Judge flipped with presentation order (position-biased) — treated
    /// as a tie.
    Inconsistent,
    /// One or both judge calls failed / unparseable.
    Unscored,
}

impl PairVerdict {
    pub fn as_str(self) -> &'static str {
        match self {
            PairVerdict::AWins => "a_wins",
            PairVerdict::BWins => "b_wins",
            PairVerdict::Inconsistent => "inconsistent",
            PairVerdict::Unscored => "unscored",
        }
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<PairVerdict> {
        Ok(match s {
            "a_wins" => PairVerdict::AWins,
            "b_wins" => PairVerdict::BWins,
            "inconsistent" => PairVerdict::Inconsistent,
            "unscored" => PairVerdict::Unscored,
            other => bail!("unknown pair verdict: {other}"),
        })
    }

    /// Checkpoint-spill encoding (one JSON value per judged pair).
    pub fn to_json(self) -> Json {
        Json::str(self.as_str())
    }

    pub fn from_json(v: &Json) -> Result<PairVerdict> {
        PairVerdict::from_str(v.as_str()?)
    }
}

/// Aggregated pairwise outcome.
#[derive(Debug)]
pub struct PairwiseResult {
    pub model_a: String,
    pub model_b: String,
    pub verdicts: Vec<PairVerdict>,
    pub a_wins: usize,
    pub b_wins: usize,
    pub inconsistent: usize,
    pub unscored: usize,
    /// Exact sign-test p-value over decisive verdicts.
    pub p_value: f64,
    /// Fraction of judged pairs where order flipped the verdict — the
    /// measured position-bias rate.
    pub position_bias_rate: f64,
    /// Adaptive stopping: the 0-based wave at which the sequential
    /// McNemar test decided significance and judging settled early
    /// (`None` = stopping disabled, or significance never decided).
    pub stopped_at_wave: Option<usize>,
    /// Pairs deliberately never judged because the comparison settled
    /// early (`verdicts.len() + pairs_saved` = the frame size).
    pub pairs_saved: usize,
}

impl PairwiseResult {
    pub fn win_rate_a(&self) -> f64 {
        let decisive = self.a_wins + self.b_wins;
        if decisive == 0 {
            0.5
        } else {
            self.a_wins as f64 / decisive as f64
        }
    }
}

impl EvalRunner {
    /// Run a pairwise comparison: infer both models' responses over `df`
    /// (through cache/rate-limit machinery via `evaluate`-style inference),
    /// then judge each response pair in both presentation orders.
    ///
    /// Judging runs through the task scheduler (`task_a.scheduler`):
    /// `inference.concurrency`-many cached judge engines per executor
    /// (both presentation orders of a batch's pairs pipeline in flight
    /// together), contiguous pair blocks as tasks, with work stealing /
    /// speculation / retry. Verdicts come back in row order, and judge
    /// response *content* is keyed on prompt text alone, so absent
    /// transient provider faults the outcome is identical to sequential
    /// judging. Injected 5xx faults are drawn per engine call sequence
    /// and the judge path (like the sequential one it replaced) does not
    /// retry, so under a nonzero `server_error_rate` *which* pairs land
    /// as `Unscored` can vary with the schedule and concurrency.
    pub fn evaluate_pairwise(
        &self,
        df: &DataFrame,
        task_a: &EvalTask,
        task_b: &EvalTask,
        rubric: &str,
        judge_provider: &str,
        judge_model: &str,
    ) -> Result<PairwiseResult> {
        let prompts = self.prepare_prompts(df, task_a)?;
        let (rows_a, _) = self.run_inference(&prompts, task_a)?;
        let (rows_b, _) = self.run_inference(&prompts, task_b)?;

        // Adaptive stopping (task A's `stopping` block): judge pairs in
        // waves and settle the comparison once the sequential McNemar
        // test decides significance — the judging stage is the pairwise
        // run's provider spend, so that is where rows are saved. Both
        // inference stages run in full (every pair must be *judgeable*
        // before the wave order is known), keeping the judge stage's
        // content address independent of where judging stops.
        let stopping = task_a.stopping.as_ref();
        let stopped_wave: Mutex<Option<usize>> = Mutex::new(None);

        // Pre-resolve shared handles: the executor closures must not
        // capture `self` (the runner holds the non-Sync PJRT runtime).
        let service = self.service(judge_provider);
        let clock = self.clock.clone();
        let cache = self.cache.clone();

        // Judging is the third provider-priced stage of a pairwise run;
        // checkpoint it like inference. The stage is content-addressed on
        // the judge configuration + both response columns, so a resumed
        // run restores verdicts only when the underlying responses are
        // byte-identical (which they are, since the inference stages
        // restore first).
        let mut parts: Vec<&str> = vec!["pairwise-judge", judge_provider, judge_model, rubric];
        for i in 0..df.len() {
            parts.push(rows_a[i].response.as_deref().unwrap_or(""));
            parts.push(rows_b[i].response.as_deref().unwrap_or(""));
        }

        // Process/remote backends: judging runs as a serializable plan
        // on crash-isolated worker processes or remote serve-worker
        // hosts (same content-addressed stage, so all backends restore
        // each other's verdicts).
        if task_a.backend != BackendKind::Thread {
            let decode_raw = |v: &Json| Ok(v.clone());
            let (stage, restored, digest) =
                self.open_checkpoint_stage("judge", parts, df.len(), &decode_raw)?;
            let stage = stage.map(std::sync::Arc::new);
            let pairs: Vec<PairInput> = (0..df.len())
                .map(|i| {
                    let row = df.row(i);
                    PairInput {
                        question: row.str(&task_a.data.question_column).to_string(),
                        reference: row.str(&task_a.data.reference_column).to_string(),
                        response_a: rows_a[i].response.clone(),
                        response_b: rows_b[i].response.clone(),
                    }
                })
                .collect();
            let cache_policy = self
                .cache
                .as_ref()
                .map(|c| c.policy())
                .unwrap_or(crate::config::CachePolicy::Disabled);
            let plan = TaskPlan {
                work: PlanWork::PairwiseJudge(PairwisePlan {
                    judge: ModelConfig {
                        provider: judge_provider.to_string(),
                        model_name: judge_model.to_string(),
                        ..Default::default()
                    },
                    rubric: rubric.to_string(),
                    concurrency: task_a.inference.concurrency.max(1),
                    pairs,
                }),
                env: self.plan_env(cache_policy),
                stage: stage.as_ref().map(|s| StagePlan {
                    dir: s.dir().display().to_string(),
                    fingerprint: digest,
                }),
                // Crash injection targets the inference stage only.
                fault: None,
            };
            let decide = |wave: usize, prefix: &[&Json]| -> Result<WaveDecision> {
                let Some(cfg) = stopping else { return Ok(WaveDecision::Continue) };
                let verdicts = prefix
                    .iter()
                    .map(|v| PairVerdict::from_json(v))
                    .collect::<Result<Vec<_>>>()?;
                Ok(pairwise_wave_decision(cfg, &stopped_wave, wave, &verdicts))
            };
            let gate = stopping.map(|cfg| WaveGate {
                first: cfg.wave_size.max(cfg.min_rows),
                step: cfg.wave_size,
                decide: &decide,
            });
            let out = self.run_plan_on_backend(
                task_a,
                &plan,
                df.len(),
                task_a.inference.batch_size,
                restored,
                None,
                None,
                stage.clone(),
                gate.as_ref(),
            )?;
            if out.rows.len() < df.len() {
                if let Some(stage) = &stage {
                    stage.record_skipped(&[(out.rows.len(), df.len())])?;
                }
            }
            // The judging stage (like its thread-path counterpart)
            // reports no scheduler stats; surface recovered deaths.
            if out.sched.executor_deaths > 0 {
                eprintln!(
                    "warning: {} executor death(s) during pairwise judging \
                     (recovered by retry; not counted in the run's scheduler stats)",
                    out.sched.executor_deaths,
                );
            }
            let verdicts = out
                .rows
                .iter()
                .map(PairVerdict::from_json)
                .collect::<Result<Vec<_>>>()?;
            let pairs_saved = df.len() - verdicts.len();
            let stopped_at = *stopped_wave.lock().unwrap();
            return Ok(aggregate_pairwise(task_a, task_b, verdicts, stopped_at, pairs_saved));
        }

        let (checkpoint_stage, restored, _) =
            self.open_checkpoint_stage("judge", parts, df.len(), &PairVerdict::from_json)?;
        let encode_verdict = |v: &PairVerdict| v.to_json();
        let checkpoint = checkpoint_stage.as_ref().map(|stage| TaskCheckpoint {
            restored,
            sink: Some(TaskSink { stage, encode: &encode_verdict }),
        });

        // Judge calls pipeline like main inference (`inference.concurrency`
        // from task A): each executor multiplexes its pair-judging calls
        // over concurrency-many cached judge engines. The judge path has
        // never retried or rate-limited (parse failures score Unscored),
        // so the pipeline runs with retries and the bucket disabled.
        let concurrency = task_a.inference.concurrency.max(1);

        let decide = |wave: usize, prefix: &[&PairVerdict]| -> Result<WaveDecision> {
            let Some(cfg) = stopping else { return Ok(WaveDecision::Continue) };
            let verdicts: Vec<PairVerdict> = prefix.iter().map(|v| **v).collect();
            Ok(pairwise_wave_decision(cfg, &stopped_wave, wave, &verdicts))
        };
        let gate = stopping.map(|cfg| WaveGate {
            first: cfg.wave_size.max(cfg.min_rows),
            step: cfg.wave_size,
            decide: &decide,
        });

        let out = run_scheduled_wave(
            df,
            task_a.executors,
            task_a.inference.batch_size,
            &task_a.scheduler,
            None,
            checkpoint,
            self.abort.as_deref(),
            gate,
            |eid| {
                let mut slots: Vec<Box<dyn InferenceEngine>> = Vec::with_capacity(concurrency);
                for _ in 0..concurrency {
                    let mut engine = SimEngine::new(
                        service.clone(),
                        judge_provider,
                        judge_model,
                        clock.clone(),
                    )?;
                    engine.initialize()?;
                    slots.push(Box::new(CachedEngine::new(engine, cache.clone())));
                }
                let rngs =
                    (0..concurrency).map(|s| Rng::with_stream(eid as u64, s as u64)).collect();
                Ok(PipelinedClient::new(
                    slots,
                    rngs,
                    RetryPolicy { max_retries: 0, ..Default::default() },
                    None,
                    clock.clone(),
                ))
            },
            |judge, df, slice| {
                if judge.concurrency() == 1 {
                    // Sequential path, identical to the pre-pipeline one.
                    let (engine, _rng, _bucket) = judge.sequential_parts();
                    let mut verdicts = Vec::with_capacity(slice.len());
                    for i in slice.indices() {
                        let (Some(resp_a), Some(resp_b)) =
                            (&rows_a[i].response, &rows_b[i].response)
                        else {
                            verdicts.push(PairVerdict::Unscored);
                            continue;
                        };
                        let row = df.row(i);
                        let question = row.str(&task_a.data.question_column);
                        let reference = row.str(&task_a.data.reference_column);

                        // Judge both presentation orders.
                        let fwd =
                            judge_once(engine, rubric, question, resp_a, resp_b, reference);
                        let rev =
                            judge_once(engine, rubric, question, resp_b, resp_a, reference);
                        verdicts.push(settle_pair(fwd, rev));
                    }
                    return Ok(verdicts);
                }

                // Pipelined path: both presentation orders of every
                // judgeable pair in the batch go in flight together
                // (requests 2k / 2k+1 are pair k's forward/reverse).
                let mut requests: Vec<InferenceRequest> = Vec::new();
                let mut judged: Vec<usize> = Vec::new();
                for (k, i) in slice.indices().enumerate() {
                    let (Some(resp_a), Some(resp_b)) =
                        (&rows_a[i].response, &rows_b[i].response)
                    else {
                        continue;
                    };
                    let row = df.row(i);
                    let question = row.str(&task_a.data.question_column);
                    let reference = row.str(&task_a.data.reference_column);
                    requests.push(InferenceRequest::new(pairwise_prompt(
                        rubric, question, resp_a, resp_b, reference,
                    )));
                    requests.push(InferenceRequest::new(pairwise_prompt(
                        rubric, question, resp_b, resp_a, reference,
                    )));
                    judged.push(k);
                }
                let batch = judge.run_batch(&requests, &|_req: &InferenceRequest| 0.0, None)?;
                let mut verdicts = vec![PairVerdict::Unscored; slice.len()];
                for (j, &k) in judged.iter().enumerate() {
                    let parse = |o: &crate::providers::retry::RetryOutcome| {
                        o.result.as_ref().ok().and_then(|r| parse_verdict(&r.text))
                    };
                    let fwd = parse(&batch.outcomes[2 * j]);
                    let rev = parse(&batch.outcomes[2 * j + 1]);
                    verdicts[k] = settle_pair(fwd, rev);
                }
                Ok(verdicts)
            },
        )?;

        if out.rows.len() < df.len() {
            if let Some(stage) = &checkpoint_stage {
                stage.record_skipped(&[(out.rows.len(), df.len())])?;
            }
        }
        let pairs_saved = df.len() - out.rows.len();
        let stopped_at = *stopped_wave.lock().unwrap();
        Ok(aggregate_pairwise(task_a, task_b, out.rows, stopped_at, pairs_saved))
    }
}

/// The pairwise stopping rule, shared by the thread and backend judging
/// paths: over the judged `[0, b)` prefix, run McNemar's test on the
/// paired per-example outcomes (a decisive verdict is exactly a
/// discordant pair, so this is the sequential sign test) at the
/// alpha-spending level for this look, and settle once significance is
/// decided. `min_rows` guards the first look against tiny-n decisions.
fn pairwise_wave_decision(
    cfg: &StoppingConfig,
    stopped: &Mutex<Option<usize>>,
    wave: usize,
    verdicts: &[PairVerdict],
) -> WaveDecision {
    if verdicts.len() < cfg.min_rows {
        return WaveDecision::Continue;
    }
    let a: Vec<f64> = verdicts
        .iter()
        .map(|v| if *v == PairVerdict::AWins { 1.0 } else { 0.0 })
        .collect();
    let b: Vec<f64> = verdicts
        .iter()
        .map(|v| if *v == PairVerdict::BWins { 1.0 } else { 0.0 })
        .collect();
    let test = mcnemar_test(&a, &b);
    if test.significant(cfg.look_alpha(wave)) {
        let mut s = stopped.lock().unwrap();
        if s.is_none() {
            *s = Some(wave);
        }
        WaveDecision::Stop
    } else {
        WaveDecision::Continue
    }
}

/// Fold per-pair verdicts into the aggregate pairwise outcome (shared by
/// the thread-closure and backend-plan judging paths).
fn aggregate_pairwise(
    task_a: &EvalTask,
    task_b: &EvalTask,
    verdicts: Vec<PairVerdict>,
    stopped_at_wave: Option<usize>,
    pairs_saved: usize,
) -> PairwiseResult {
    let (mut a_wins, mut b_wins, mut inconsistent, mut unscored) = (0, 0, 0, 0);
    for verdict in &verdicts {
        match verdict {
            PairVerdict::AWins => a_wins += 1,
            PairVerdict::BWins => b_wins += 1,
            PairVerdict::Inconsistent => inconsistent += 1,
            PairVerdict::Unscored => unscored += 1,
        }
    }

    let judged = a_wins + b_wins + inconsistent;
    PairwiseResult {
        model_a: format!("{}/{}", task_a.model.provider, task_a.model.model_name),
        model_b: format!("{}/{}", task_b.model.provider, task_b.model.model_name),
        verdicts,
        a_wins,
        b_wins,
        inconsistent,
        unscored,
        p_value: binom_test_half(a_wins.min(b_wins) as u64, (a_wins + b_wins) as u64),
        position_bias_rate: if judged == 0 {
            0.0
        } else {
            inconsistent as f64 / judged as f64
        },
        stopped_at_wave,
        pairs_saved,
    }
}

/// Issue one pairwise-judge call and parse the verdict letter. Shared
/// with the plan-executor path (`coordinator::plan_exec`) so both
/// backends settle pairs through the same code.
pub(crate) fn judge_once(
    judge: &mut dyn InferenceEngine,
    rubric: &str,
    question: &str,
    first: &str,
    second: &str,
    reference: &str,
) -> Option<char> {
    let req = InferenceRequest::new(pairwise_prompt(rubric, question, first, second, reference));
    judge.infer(&req).ok().and_then(|r| parse_verdict(&r.text))
}

/// Combine both presentation orders into a verdict: fwd 'A' means A wins;
/// rev 'A' means B wins (order swapped).
pub(crate) fn settle_pair(fwd: Option<char>, rev: Option<char>) -> PairVerdict {
    match (fwd, rev) {
        (Some('A'), Some('B')) => PairVerdict::AWins,
        (Some('B'), Some('A')) => PairVerdict::BWins,
        (Some(_), Some(_)) => PairVerdict::Inconsistent,
        _ => PairVerdict::Unscored,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::providers::simulated::SimServiceConfig;
    use crate::ratelimit::VirtualClock;

    fn fast_runner() -> EvalRunner {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig {
            server_error_rate: 0.0,
            unparseable_rate: 0.0,
            sleep_latency: false,
            ..Default::default()
        };
        r
    }

    #[test]
    fn strong_model_wins_pairwise() {
        let runner = fast_runner();
        let df = synth::generate(
            120,
            95,
            synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();
        let mut task_a = EvalTask::default();
        task_a.model.model_name = "gpt-4o".into();
        let mut task_b = task_a.clone();
        task_b.model.model_name = "gpt-3.5-turbo".into();

        let r = runner
            .evaluate_pairwise(&df, &task_a, &task_b, "accuracy", "openai", "gpt-4o")
            .unwrap();
        assert!(r.a_wins > r.b_wins, "a {} b {}", r.a_wins, r.b_wins);
        assert!(r.win_rate_a() > 0.6, "win rate {}", r.win_rate_a());
        assert!(r.p_value < 0.05, "p {}", r.p_value);
        assert_eq!(r.verdicts.len(), 120);
        assert_eq!(
            r.a_wins + r.b_wins + r.inconsistent + r.unscored,
            120,
            "verdict accounting"
        );
    }

    #[test]
    fn identical_models_tie() {
        let runner = fast_runner();
        let df = synth::generate_default(60, 96);
        let task = EvalTask::default();
        let r = runner
            .evaluate_pairwise(&df, &task, &task, "accuracy", "openai", "gpt-4o")
            .unwrap();
        // Identical responses → the judge's overlap heuristic sees equal
        // quality; forward order says A (ties break to first), reverse
        // also says first → inconsistent (position-symmetric) — so no
        // decisive wins should dominate.
        assert!(r.p_value > 0.05 || r.a_wins.abs_diff(r.b_wins) < 8, "{r:?}");
    }

    #[test]
    fn pairwise_run_resumes_with_zero_provider_calls() {
        // A completed pairwise run (two inference stages + one judging
        // stage) resumed from its checkpoint issues no provider calls at
        // all and reproduces the exact same verdicts.
        let df = synth::generate(
            80,
            99,
            synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();
        let mut task_a = EvalTask::default();
        task_a.inference.cache_policy = crate::config::CachePolicy::Disabled;
        task_a.scheduler.speculation = false;
        task_a.scheduler.adaptive_split = false;
        task_a.model.model_name = "gpt-4o".into();
        let mut task_b = task_a.clone();
        task_b.model.model_name = "gpt-3.5-turbo".into();

        let dir = std::env::temp_dir()
            .join("slleval-coord-test")
            .join(format!("pairwise-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, false).unwrap();
        let r1 = runner
            .evaluate_pairwise(&df, &task_a, &task_b, "accuracy", "openai", "gpt-4o")
            .unwrap();
        let calls_first = runner.service("openai").stats().calls;
        assert!(calls_first > 0);

        let mut runner = fast_runner();
        runner.attach_checkpoint(&dir, true).unwrap();
        let r2 = runner
            .evaluate_pairwise(&df, &task_a, &task_b, "accuracy", "openai", "gpt-4o")
            .unwrap();
        assert_eq!(
            runner.service("openai").stats().calls,
            0,
            "a fully checkpointed pairwise run must not issue any provider calls"
        );
        assert_eq!(r1.verdicts, r2.verdicts);
        assert_eq!((r1.a_wins, r1.b_wins), (r2.a_wins, r2.b_wins));
        assert_eq!(r1.p_value, r2.p_value);
    }

    #[test]
    fn pairwise_stopping_settles_once_significance_is_decided() {
        // A strong-vs-weak pair separates fast: the sequential McNemar
        // test decides significance within the first waves, the judging
        // stage settles early, and the saved pairs are accounted.
        let runner = fast_runner();
        let df = synth::generate(
            300,
            95,
            synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();
        let mut task_a = EvalTask::default();
        task_a.model.model_name = "gpt-4o".into();
        task_a.stopping = Some(StoppingConfig {
            ci_half_width: 0.05,
            alpha: 0.05,
            wave_size: 60,
            min_rows: 40,
            spend_alpha: true,
        });
        let mut task_b = task_a.clone();
        task_b.model.model_name = "gpt-3.5-turbo".into();

        let r = runner
            .evaluate_pairwise(&df, &task_a, &task_b, "accuracy", "openai", "gpt-4o")
            .unwrap();
        assert!(r.stopped_at_wave.is_some(), "{r:?}");
        assert!(r.pairs_saved > 0, "{r:?}");
        assert_eq!(r.verdicts.len() + r.pairs_saved, 300, "pair accounting");
        assert!(r.p_value < 0.05, "p {}", r.p_value);
        assert!(r.a_wins > r.b_wins);
    }

    #[test]
    fn pairwise_stopping_never_decides_on_identical_models() {
        // Identical models: no significance to find — the gated run
        // judges the whole frame and saves nothing.
        let runner = fast_runner();
        let df = synth::generate_default(80, 96);
        let mut task = EvalTask::default();
        task.stopping = Some(StoppingConfig {
            ci_half_width: 0.05,
            alpha: 0.05,
            wave_size: 30,
            min_rows: 20,
            spend_alpha: true,
        });
        let r = runner
            .evaluate_pairwise(&df, &task, &task, "accuracy", "openai", "gpt-4o")
            .unwrap();
        assert_eq!(r.stopped_at_wave, None, "{r:?}");
        assert_eq!(r.pairs_saved, 0);
        assert_eq!(r.verdicts.len(), 80);
    }

    #[test]
    fn position_bias_measured_with_biased_judge() {
        // A weak judge (quality well below 1) picks the degraded verdict —
        // the *loser* — sometimes; judging both orders detects the
        // inconsistency instead of silently favouring one position.
        let runner = fast_runner();
        let df = synth::generate(
            150,
            97,
            synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();
        let mut task_a = EvalTask::default();
        task_a.model.model_name = "gpt-4o".into();
        let mut task_b = task_a.clone();
        task_b.model.model_name = "gpt-3.5-turbo".into();
        let r = runner
            .evaluate_pairwise(&df, &task_a, &task_b, "accuracy", "openai", "gpt-3.5-turbo")
            .unwrap();
        // The weak judge errs on some pairs; errors in only one order show
        // up as Inconsistent rather than polluting the win counts.
        assert!(r.position_bias_rate > 0.0, "expected measurable bias");
        assert!(r.a_wins > r.b_wins, "signal should survive debiasing");
    }
}
