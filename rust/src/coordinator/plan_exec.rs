//! Task-side execution of serializable plans: the executor-local state
//! the scheduler closures used to capture, rebuilt from a
//! [`TaskPlan`] instead.
//!
//! One [`PlanExecutor`] is the moral equivalent of the old
//! `init(executor_id)` closure result — slot engines, retry policy,
//! token bucket, cache handle — plus the `process(state, df, slice)`
//! body for each work kind. It runs identically in two places:
//!
//! - **in process** ([`crate::sched::backend::ThreadBackend`]): the
//!   driver builds a [`PlanHost`] sharing its live clock / provider
//!   service / cache handles, so thread-backed plan execution hits the
//!   exact same endpoint state the closure path did;
//! - **out of process** (`slleval worker`): [`PlanHost::from_plan`]
//!   reconstructs the environment from the plan — its own clock, its own
//!   simulated provider endpoint (deterministic content draws make the
//!   responses identical), its own cache connection (Delta commits
//!   are multi-writer safe).
//!
//! Completed tasks spill **worker-side** into the plan's checkpoint
//! stage before the result is reported, so even `kill -9` between
//! "spilled" and "reported" loses nothing on resume.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::cached_engine::CachedEngine;
use super::pairwise::{judge_once, settle_pair, PairVerdict};
use super::runner::RowInference;
use crate::cache::ResponseCache;
use crate::checkpoint::StageCheckpoint;
use crate::config::{CachePolicy, ModelConfig};
use crate::metrics::judge::{pairwise_prompt, parse_verdict};
use crate::metrics::{builtin_registry, MetricContext, ResolvedMetric};
use crate::providers::pipeline::PipelinedClient;
use crate::providers::retry::{infer_with_retry, RetryOutcome, RetryPolicy};
use crate::providers::simulated::{SimEngine, SimService};
use crate::providers::tokenizer::estimate_request_tokens;
use crate::providers::{InferenceEngine, InferenceRequest};
use crate::ratelimit::{Clock, RealClock, TokenBucket, VirtualClock};
use crate::sched::backend::{PlanTaskRunner, RunnerFactory, TaskResultMsg, TaskSpec};
use crate::sched::plan::{PlanWork, TaskPlan};
use crate::sched::wire::{write_frame_shared, SharedWriter};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Live handles a plan executor runs against. The driver shares its own;
/// a worker process rebuilds them from the plan environment.
pub struct PlanHost {
    pub clock: Arc<dyn Clock>,
    /// Shared provider endpoint (`None` for pure-metric plans).
    pub service: Option<Arc<SimService>>,
    pub cache: Option<Arc<ResponseCache>>,
}

impl PlanHost {
    /// Worker-side reconstruction: own clock (virtual in fast mode), own
    /// simulated endpoint, own cache connection.
    pub fn from_plan(plan: &TaskPlan) -> Result<PlanHost> {
        let clock: Arc<dyn Clock> = if plan.env.virtual_clock {
            VirtualClock::new()
        } else {
            Arc::new(RealClock::new())
        };
        let service = plan
            .provider()
            .map(|p| SimService::new(p, plan.env.service.clone(), clock.clone()));
        let cache = match &plan.env.cache_dir {
            Some(dir) if plan.env.cache_policy != CachePolicy::Disabled => Some(Arc::new(
                ResponseCache::open(Path::new(dir), plan.env.cache_policy)
                    .with_context(|| format!("opening worker cache at {dir}"))?,
            )),
            _ => None,
        };
        // Inference plans carry the task's data-skipping switch; the
        // worker's own cache connection honours it like the driver's.
        if let (Some(cache), PlanWork::Inference(p)) = (&cache, &plan.work) {
            cache.set_skipping(p.inference.cache_skipping);
        }
        Ok(PlanHost { clock, service, cache })
    }
}

enum ExecState {
    Inference { client: PipelinedClient, policy: RetryPolicy },
    Metric { metric: ResolvedMetric },
    Pairwise { client: PipelinedClient },
}

/// One executor's plan-built state + the per-batch execution bodies.
pub struct PlanExecutor {
    plan: Arc<TaskPlan>,
    eid: usize,
    clock: Arc<dyn Clock>,
    cache: Option<Arc<ResponseCache>>,
    stage: Option<StageCheckpoint>,
    /// When set (serve-worker mode), completed-task spills are uploaded
    /// to the driver as `{"type":"spill",...}` frames instead of written
    /// to a local stage directory — remote workers share no filesystem
    /// with the driver.
    spill_frames: Option<SharedWriter>,
    state: ExecState,
}

impl PlanExecutor {
    pub fn new(plan: Arc<TaskPlan>, eid: usize, host: PlanHost) -> Result<PlanExecutor> {
        let state = match &plan.work {
            PlanWork::Inference(p) => {
                let service =
                    host.service.clone().context("inference plan needs a provider service")?;
                let concurrency = p.inference.concurrency.max(1);
                // One engine per concurrency slot — the same widened
                // `_ENGINE_CACHE` construction (and rng streams) as the
                // closure path, so slot 0 at concurrency 1 is exactly
                // the old single engine.
                let mut slots: Vec<Box<dyn InferenceEngine>> = Vec::with_capacity(concurrency);
                for _ in 0..concurrency {
                    let mut engine = SimEngine::new(
                        service.clone(),
                        &p.model.provider,
                        &p.model.model_name,
                        host.clock.clone(),
                    )?;
                    engine.initialize()?;
                    slots.push(Box::new(engine));
                }
                let rngs = (0..concurrency)
                    .map(|s| Rng::with_stream(p.seed, eid as u64 ^ ((s as u64) << 32)))
                    .collect();
                let bucket = TokenBucket::per_executor(
                    p.inference.rate_limit_rpm,
                    p.inference.rate_limit_tpm,
                    p.executors,
                    host.clock.as_ref(),
                );
                let policy = RetryPolicy {
                    max_retries: p.inference.max_retries,
                    base_delay: p.inference.retry_delay,
                    ..Default::default()
                };
                ExecState::Inference {
                    client: PipelinedClient::new(
                        slots,
                        rngs,
                        policy,
                        Some(bucket),
                        host.clock.clone(),
                    ),
                    policy,
                }
            }
            PlanWork::MetricScore(p) => {
                // Only registry built-ins can cross a process boundary;
                // the driver gates custom metrics onto the thread path.
                let metric = builtin_registry().resolve(&p.metric)?;
                ExecState::Metric { metric }
            }
            PlanWork::PairwiseJudge(p) => {
                let service =
                    host.service.clone().context("pairwise plan needs a provider service")?;
                let concurrency = p.concurrency.max(1);
                let mut slots: Vec<Box<dyn InferenceEngine>> = Vec::with_capacity(concurrency);
                for _ in 0..concurrency {
                    let mut engine = SimEngine::new(
                        service.clone(),
                        &p.judge.provider,
                        &p.judge.model_name,
                        host.clock.clone(),
                    )?;
                    engine.initialize()?;
                    slots.push(Box::new(CachedEngine::new(engine, host.cache.clone())));
                }
                let rngs =
                    (0..concurrency).map(|s| Rng::with_stream(eid as u64, s as u64)).collect();
                ExecState::Pairwise {
                    client: PipelinedClient::new(
                        slots,
                        rngs,
                        RetryPolicy { max_retries: 0, ..Default::default() },
                        None,
                        host.clock.clone(),
                    ),
                }
            }
        };
        // The stage was created (and fingerprint-bound) by the driver;
        // spills are best-effort durability, so a missing/unreadable
        // stage degrades resume rather than failing the executor.
        let stage = plan.stage.as_ref().and_then(|s| {
            match StageCheckpoint::open(Path::new(&s.dir)) {
                Ok(stage) => Some(stage),
                Err(e) => {
                    eprintln!("warning: executor {eid} cannot open checkpoint stage: {e:#}");
                    None
                }
            }
        });
        Ok(PlanExecutor {
            plan,
            eid,
            clock: host.clock,
            cache: host.cache,
            stage,
            spill_frames: None,
            state,
        })
    }

    /// Redirect completed-task spills to frame upload over `sink`
    /// (serve-worker mode). Disables the local stage: a remote worker's
    /// filesystem is not the driver's, so a local spill would be both
    /// useless for `--resume` and misleading on loopback test setups.
    pub fn spill_to_frames(&mut self, sink: SharedWriter) {
        self.stage = None;
        self.spill_frames = Some(sink);
    }

    /// Execute one batch of rows `[start, end)`, returning one JSON value
    /// per row plus the attempt's provider spend.
    fn run_batch_rows(
        &mut self,
        start: usize,
        end: usize,
        spend: &mut (u64, u64, f64),
        peak: &mut usize,
    ) -> Result<Vec<Json>> {
        match &mut self.state {
            ExecState::Inference { client, policy } => {
                let PlanWork::Inference(p) = &self.plan.work else {
                    bail!("plan/executor state mismatch: inference executor got non-inference work")
                };
                let estimate = |req: &InferenceRequest| {
                    estimate_request_tokens(&req.prompt, req.max_tokens) as f64
                };
                let mut rows: Vec<Option<RowInference>> = (start..end).map(|_| None).collect();
                if client.concurrency() == 1 {
                    // Sequential path — the exact pre-pipeline per-row
                    // loop: cache lookup, blocking admission, retry,
                    // cache write interleaved.
                    let (engine, rng, bucket) = client.sequential_parts();
                    let Some(bucket) = bucket else {
                        bail!("inference client built without a rate-limit bucket")
                    };
                    for i in start..end {
                        let prompt = &p.prompts[i];
                        if let Some(hit) = cache_lookup(
                            &self.cache,
                            &p.model,
                            p.inference.cache_policy,
                            prompt,
                            i,
                        )? {
                            rows[i - start] = Some(hit);
                            continue;
                        }
                        let mut req = InferenceRequest::new(prompt.as_str());
                        req.max_tokens = p.model.max_tokens;
                        req.temperature = p.model.temperature;
                        bucket.acquire(estimate(&req), self.clock.as_ref());
                        let outcome =
                            infer_with_retry(engine, &req, policy, self.clock.as_ref(), rng);
                        *peak = (*peak).max(1);
                        spend.0 += outcome.attempts as u64;
                        if let Ok(resp) = &outcome.result {
                            spend.1 += (outcome.attempts - 1) as u64;
                            spend.2 += resp.cost_usd;
                        }
                        rows[i - start] = Some(assemble(
                            &self.cache,
                            &p.model,
                            p.inference.cache_policy,
                            outcome,
                            prompt,
                        )?);
                    }
                } else {
                    // Pipelined path: resolve cache hits up front, then
                    // overlap every miss's latency across the slots.
                    let mut miss_at: Vec<usize> = Vec::new();
                    let mut miss_reqs: Vec<InferenceRequest> = Vec::new();
                    for i in start..end {
                        let prompt = &p.prompts[i];
                        if let Some(hit) = cache_lookup(
                            &self.cache,
                            &p.model,
                            p.inference.cache_policy,
                            prompt,
                            i,
                        )? {
                            rows[i - start] = Some(hit);
                            continue;
                        }
                        let mut req = InferenceRequest::new(prompt.as_str());
                        req.max_tokens = p.model.max_tokens;
                        req.temperature = p.model.temperature;
                        miss_at.push(i - start);
                        miss_reqs.push(req);
                    }
                    let batch_spend = Mutex::new((0u64, 0u64, 0.0f64));
                    let account = |outcome: &RetryOutcome| {
                        let mut s = batch_spend.lock().unwrap_or_else(|p| p.into_inner());
                        s.0 += outcome.attempts as u64;
                        if let Ok(resp) = &outcome.result {
                            s.1 += (outcome.attempts - 1) as u64;
                            s.2 += resp.cost_usd;
                        }
                    };
                    let batch = client.run_batch(&miss_reqs, &estimate, Some(&account))?;
                    *peak = (*peak).max(batch.stats.peak_in_flight);
                    let s = batch_spend.into_inner().unwrap_or_else(|p| p.into_inner());
                    spend.0 += s.0;
                    spend.1 += s.1;
                    spend.2 += s.2;
                    for (j, outcome) in batch.outcomes.into_iter().enumerate() {
                        rows[miss_at[j]] = Some(assemble(
                            &self.cache,
                            &p.model,
                            p.inference.cache_policy,
                            outcome,
                            &miss_reqs[j].prompt,
                        )?);
                    }
                }
                let mut out = Vec::with_capacity(rows.len());
                for (off, r) in rows.into_iter().enumerate() {
                    match r {
                        Some(v) => out.push(v.to_json()),
                        None => bail!("row {} never settled in batch [{start}, {end})", start + off),
                    }
                }
                Ok(out)
            }
            ExecState::Metric { metric } => {
                let PlanWork::MetricScore(p) = &self.plan.work else {
                    bail!("plan/executor state mismatch: metric executor got non-metric work")
                };
                let batch =
                    metric.score_batch(&MetricContext::detached(), &p.examples[start..end])?;
                validate_pure_batch(metric.name(), &batch, end - start)?;
                Ok(batch
                    .values
                    .into_iter()
                    .map(|v| v.map(Json::num).unwrap_or(Json::Null))
                    .collect())
            }
            ExecState::Pairwise { client } => {
                let PlanWork::PairwiseJudge(p) = &self.plan.work else {
                    bail!("plan/executor state mismatch: pairwise executor got non-pairwise work")
                };
                let mut verdicts = vec![PairVerdict::Unscored; end - start];
                if client.concurrency() == 1 {
                    let (engine, _rng, _bucket) = client.sequential_parts();
                    for i in start..end {
                        let pair = &p.pairs[i];
                        let (Some(resp_a), Some(resp_b)) = (&pair.response_a, &pair.response_b)
                        else {
                            continue;
                        };
                        let fwd = judge_once(
                            engine,
                            &p.rubric,
                            &pair.question,
                            resp_a,
                            resp_b,
                            &pair.reference,
                        );
                        let rev = judge_once(
                            engine,
                            &p.rubric,
                            &pair.question,
                            resp_b,
                            resp_a,
                            &pair.reference,
                        );
                        *peak = (*peak).max(1);
                        verdicts[i - start] = settle_pair(fwd, rev);
                    }
                } else {
                    // Both presentation orders of every judgeable pair go
                    // in flight together (2k / 2k+1 = pair k fwd/rev).
                    let mut requests: Vec<InferenceRequest> = Vec::new();
                    let mut judged: Vec<usize> = Vec::new();
                    for i in start..end {
                        let pair = &p.pairs[i];
                        let (Some(resp_a), Some(resp_b)) = (&pair.response_a, &pair.response_b)
                        else {
                            continue;
                        };
                        requests.push(InferenceRequest::new(pairwise_prompt(
                            &p.rubric,
                            &pair.question,
                            resp_a,
                            resp_b,
                            &pair.reference,
                        )));
                        requests.push(InferenceRequest::new(pairwise_prompt(
                            &p.rubric,
                            &pair.question,
                            resp_b,
                            resp_a,
                            &pair.reference,
                        )));
                        judged.push(i - start);
                    }
                    let batch =
                        client.run_batch(&requests, &|_req: &InferenceRequest| 0.0, None)?;
                    *peak = (*peak).max(batch.stats.peak_in_flight);
                    for (j, &k) in judged.iter().enumerate() {
                        let parse = |o: &RetryOutcome| {
                            o.result.as_ref().ok().and_then(|r| parse_verdict(&r.text))
                        };
                        let fwd = parse(&batch.outcomes[2 * j]);
                        let rev = parse(&batch.outcomes[2 * j + 1]);
                        verdicts[k] = settle_pair(fwd, rev);
                    }
                }
                Ok(verdicts.into_iter().map(|v| v.to_json()).collect())
            }
        }
    }
}

impl PlanTaskRunner for PlanExecutor {
    fn run(&mut self, spec: &TaskSpec, batch_size: usize) -> Result<TaskResultMsg> {
        let batch_size = batch_size.max(1);
        let total = self.plan.total_rows();
        anyhow::ensure!(
            spec.start < spec.end && spec.end <= total,
            "task range [{}, {}) out of bounds for a {total}-row plan",
            spec.start,
            spec.end
        );
        let mut rows: Vec<Json> = Vec::with_capacity(spec.end - spec.start);
        let mut spend = (0u64, 0u64, 0.0f64);
        let mut peak = 0usize;
        let mut busy_secs = 0.0;
        let mut batches = 0usize;
        let mut cursor = spec.start;
        while cursor < spec.end {
            let batch_end = (cursor + batch_size).min(spec.end);
            // lint:allow(determinism): busy_secs is wall-clock telemetry by design
            let bt0 = Instant::now();
            let batch_rows = self.run_batch_rows(cursor, batch_end, &mut spend, &mut peak)?;
            busy_secs += bt0.elapsed().as_secs_f64();
            batches += 1;
            rows.extend(batch_rows);
            cursor = batch_end;
        }

        // Worker-side checkpoint spill, *before* reporting: a crash
        // between spill and report costs nothing on resume, and racing
        // twins of the same range are first-writer-wins. Serve-mode
        // workers upload the spill as a frame (the driver records it);
        // local workers write the shared stage directory directly.
        if let Some(sink) = &self.spill_frames {
            let frame = Json::obj(vec![
                ("type", Json::str("spill")),
                ("start", Json::num(spec.start as f64)),
                ("end", Json::num(spec.end as f64)),
                ("attempt", Json::num(spec.attempt as f64)),
                ("rows", Json::arr(rows.clone())),
            ]);
            if let Err(e) = write_frame_shared(sink, &frame) {
                eprintln!(
                    "warning: spill upload failed for rows [{}, {}): {e:#}",
                    spec.start, spec.end
                );
            }
        } else if let Some(stage) = &self.stage {
            let lines: Vec<String> = rows.iter().map(|r| r.to_string()).collect();
            if let Err(e) =
                stage.record_task(spec.start, spec.end, spec.attempt, self.eid, &lines)
            {
                eprintln!(
                    "warning: checkpoint write failed for rows [{}, {}): {e:#}",
                    spec.start, spec.end
                );
            }
        }

        Ok(TaskResultMsg {
            task_id: spec.task_id,
            start: spec.start,
            end: spec.end,
            attempt: spec.attempt,
            speculative: spec.speculative,
            rows_processed: rows.len(),
            rows,
            batches,
            busy_secs,
            peak_in_flight: peak,
            api_calls: spend.0,
            retries: spend.1,
            cost_usd: spend.2,
        })
    }

    fn finish(&mut self) {
        if let Some(cache) = &self.cache {
            if let Err(e) = cache.flush() {
                eprintln!("warning: executor {} cache flush failed: {e:#}", self.eid);
            }
        }
    }
}

/// Build a [`RunnerFactory`] for an in-process thread backend: each
/// executor thread constructs its own [`PlanExecutor`] against the
/// driver's shared clock / endpoint / cache handles.
pub fn thread_runner_factory(
    plan: Arc<TaskPlan>,
    clock: Arc<dyn Clock>,
    service: Option<Arc<SimService>>,
    cache: Option<Arc<ResponseCache>>,
) -> RunnerFactory {
    Arc::new(move |eid| {
        let host =
            PlanHost { clock: clock.clone(), service: service.clone(), cache: cache.clone() };
        Ok(Box::new(PlanExecutor::new(plan.clone(), eid, host)?) as Box<dyn PlanTaskRunner>)
    })
}

/// Shared pure-metric batch contract: exactly one value per row, and no
/// `unparseable` count (that field tracks unparseable *judge* responses;
/// a pure metric has none, and a batch count could not survive
/// speculative duplicate attempts anyway — unscorable rows are `None`s).
/// Used by both the closure-scheduler and plan-executor scoring paths.
pub(crate) fn validate_pure_batch(
    name: &str,
    batch: &crate::metrics::ScoreBatch,
    expected: usize,
) -> Result<()> {
    anyhow::ensure!(
        batch.values.len() == expected,
        "metric '{name}' returned {} values for a {expected}-row batch",
        batch.values.len()
    );
    anyhow::ensure!(
        batch.unparseable == 0,
        "pure metric '{name}' reported {} unparseable responses; \
         pure metrics must score unscorable rows as None",
        batch.unparseable
    );
    Ok(())
}

/// Cache lookup for one prompt; `Some` short-circuits inference — the
/// single implementation of the inference-stage cache policy semantics
/// (including strict replay), shared by the closure scheduler's UDF and
/// the plan executor.
pub(crate) fn cache_lookup(
    cache: &Option<Arc<ResponseCache>>,
    model: &ModelConfig,
    policy: CachePolicy,
    prompt: &str,
    i: usize,
) -> Result<Option<RowInference>> {
    let replay_strict = policy == CachePolicy::Replay;
    if policy.reads() {
        if let Some(cache) = cache {
            match cache.get(
                prompt,
                &model.model_name,
                &model.provider,
                model.temperature,
                model.max_tokens,
            ) {
                Ok(Some(entry)) => {
                    return Ok(Some(RowInference {
                        response: Some(entry.response_text),
                        from_cache: true,
                        latency_ms: 0.0,
                        cost_usd: 0.0,
                        attempts: 0,
                        error: None,
                    }));
                }
                Ok(None) => {}
                Err(e) => {
                    if replay_strict {
                        return Err(e);
                    }
                }
            }
        } else if replay_strict {
            bail!("replay mode requires an open cache");
        }
    }
    if replay_strict {
        bail!("replay mode: cache miss for example {i}");
    }
    Ok(None)
}

/// Row assembly for one settled provider outcome: cache write +
/// [`RowInference`] — the single implementation shared by the closure
/// scheduler's UDF and the plan executor.
pub(crate) fn assemble(
    cache: &Option<Arc<ResponseCache>>,
    model: &ModelConfig,
    policy: CachePolicy,
    outcome: RetryOutcome,
    prompt: &str,
) -> Result<RowInference> {
    match outcome.result {
        Ok(resp) => {
            if policy.writes() {
                if let Some(cache) = cache {
                    cache.put(
                        prompt,
                        &model.model_name,
                        &model.provider,
                        model.temperature,
                        model.max_tokens,
                        &resp,
                    )?;
                }
            }
            Ok(RowInference {
                response: Some(resp.text),
                from_cache: false,
                latency_ms: resp.latency_ms,
                cost_usd: resp.cost_usd,
                attempts: outcome.attempts,
                error: None,
            })
        }
        Err(e) => Ok(RowInference {
            response: None,
            from_cache: false,
            latency_ms: 0.0,
            cost_usd: 0.0,
            attempts: outcome.attempts,
            error: Some(e.to_string()),
        }),
    }
}

