//! The `slleval worker` / `slleval serve-worker` entry points: one
//! out-of-process executor, over pipes or TCP.
//!
//! **Pipe mode** ([`worker_main`]): spawned by
//! [`crate::sched::backend::ProcessBackend`] with stdin/stdout pipes.
//! Protocol (length-prefixed JSON frames, see [`crate::sched::backend`]):
//! a `hello` frame carries the serialized
//! [`TaskPlan`](crate::sched::plan::TaskPlan) + this worker's executor
//! id; the worker rebuilds its executor-local state from the plan
//! ([`PlanHost::from_plan`]), answers `ready` (or `init_error`), then
//! executes `task` frames one at a time. A `plan` frame *re-arms* the
//! worker for the next stage of the same run (persistent fleets): the
//! current executor is finished (cache flush), a new one is built from
//! the shipped plan, and a fresh `ready` is sent. `shutdown` or EOF ends
//! the session.
//!
//! **Serve mode** ([`serve_worker_main`]): a per-host daemon
//! (`slleval serve-worker --listen <addr>`) accepting TCP connections
//! from [`crate::sched::remote::RemoteBackend`] — one connection per
//! granted executor slot, each served by its own thread running the
//! identical session protocol, plus two TCP-specific behaviours: a
//! heartbeat frame every second (so the driver's read timeout
//! distinguishes a long-running task from a dead host) and
//! checkpoint-spill *upload* — completed-task rows go back to the driver
//! as `spill` frames instead of a local stage directory the driver could
//! never read.
//!
//! All diagnostics go to stderr — stdout carries protocol frames (pipe
//! mode) or the `listening on <addr>` line (serve mode) only.
//!
//! The plan's [`WorkerFault`](crate::sched::plan::WorkerFault) hook makes
//! crash tests deterministic offline: the targeted executor
//! `std::process::abort()`s while executing its N-th task — a genuine
//! hard death (no unwinding, no cleanup, result never sent), exactly
//! what a `kill -9` or OOM kill looks like to the driver. In serve mode
//! the abort takes the whole daemon down — every executor on the host at
//! once, which is precisely what host-death handling is for.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::plan_exec::{PlanExecutor, PlanHost};
use crate::sched::backend::{PlanTaskRunner, TaskSpec};
use crate::sched::plan::TaskPlan;
use crate::sched::wire::{read_frame, write_frame_shared, SharedWriter};
use crate::util::json::Json;

/// Run the worker protocol over this process's stdin/stdout. Returns when
/// the driver shuts the pipe down; protocol violations are fatal (the
/// driver sees EOF and treats this executor as dead).
pub fn worker_main() -> Result<()> {
    let stdin = std::io::stdin();
    let mut input = stdin.lock();
    let output: SharedWriter = Arc::new(Mutex::new(Box::new(std::io::stdout())));
    serve_session(&mut input, &output, false)
}

/// One worker session over any frame transport: handshake (`hello`),
/// task execution, mid-session re-arms (`plan`), shutdown. With
/// `spill_frames`, completed-task spills are uploaded as frames instead
/// of written to a local stage (serve mode — no shared filesystem).
fn serve_session(
    input: &mut dyn std::io::Read,
    output: &SharedWriter,
    spill_frames: bool,
) -> Result<()> {
    let Some(mut pending) = read_frame(input)? else {
        return Ok(()); // connection closed before the handshake
    };
    loop {
        let ty = pending.str_or("type", "");
        anyhow::ensure!(
            ty == "hello" || ty == "plan",
            "protocol error: expected hello/plan frame, got '{ty}'"
        );
        let eid = pending.get("executor_id")?.as_usize()?;
        let batch_size = pending.usize_or("batch_size", 1).max(1);
        let plan =
            TaskPlan::from_json(pending.get("plan")?).context("parsing shipped task plan")?;
        let fault = plan.fault.filter(|f| f.executor_id == eid);

        let mut executor = match PlanHost::from_plan(&plan)
            .and_then(|host| PlanExecutor::new(Arc::new(plan), eid, host))
        {
            Ok(mut e) => {
                if spill_frames {
                    e.spill_to_frames(output.clone());
                }
                e
            }
            Err(e) => {
                let msg = Json::obj(vec![
                    ("type", Json::str("init_error")),
                    ("error", Json::str(&format!("{e:#}"))),
                ]);
                write_frame_shared(output, &msg)?;
                return Ok(());
            }
        };
        write_frame_shared(output, &Json::obj(vec![("type", Json::str("ready"))]))?;

        let mut received = 0usize;
        let next = loop {
            let Some(frame) = read_frame(input)? else { break None };
            match frame.str_or("type", "") {
                "task" => {
                    let spec = TaskSpec::from_json(&frame).context("parsing task frame")?;
                    received += 1;
                    let result = executor.run(&spec, batch_size);
                    // Deterministic hard death: computed but never
                    // reported — the driver pays for exactly this
                    // in-flight task.
                    if let Some(f) = fault {
                        if received == f.kill_after_tasks {
                            let _ = std::io::stderr().write_all(
                                format!(
                                    "worker {eid}: fault injection — aborting on task {} \
                                     [{}, {})\n",
                                    spec.task_id, spec.start, spec.end
                                )
                                .as_bytes(),
                            );
                            std::process::abort();
                        }
                    }
                    match result {
                        Ok(msg) => write_frame_shared(output, &msg.to_json())?,
                        Err(e) => {
                            let msg = Json::obj(vec![
                                ("type", Json::str("task_error")),
                                ("task_id", Json::num(spec.task_id as f64)),
                                ("error", Json::str(&format!("{e:#}"))),
                            ]);
                            write_frame_shared(output, &msg)?;
                        }
                    }
                }
                // Re-arm: the driver's next stage ships a new plan over
                // the live session instead of respawning the worker.
                "plan" => break Some(frame),
                "shutdown" => break None,
                other => {
                    eprintln!("worker {eid}: ignoring unknown frame type '{other}'");
                }
            }
        };
        // Flush buffered cache writes before the session ends (or the
        // next plan's executor takes over) so later runs/rescore see
        // what this worker paid for.
        executor.finish();
        match next {
            Some(frame) => pending = frame,
            None => return Ok(()),
        }
    }
}

/// Serve one accepted TCP connection: heartbeats + the worker session
/// with spill upload. Public so loopback benches/tests can run the serve
/// loop in-process without a child daemon.
pub fn serve_connection(stream: std::net::TcpStream) -> Result<()> {
    let _ = stream.set_nodelay(true);
    let mut input = stream.try_clone().context("cloning connection for reads")?;
    let output: SharedWriter = Arc::new(Mutex::new(Box::new(
        stream.try_clone().context("cloning connection for writes")?,
    )));

    // One whole heartbeat frame per second: the driver's read timeout
    // then distinguishes "busy with a long task" from "host is gone".
    let stop = Arc::new(AtomicBool::new(false));
    let hb_stop = stop.clone();
    let hb_out = output.clone();
    let heartbeat = std::thread::Builder::new()
        .name("slleval-heartbeat".into())
        .spawn(move || {
            let frame = Json::obj(vec![("type", Json::str("heartbeat"))]);
            while !hb_stop.load(Ordering::Relaxed) {
                if write_frame_shared(&hb_out, &frame).is_err() {
                    return; // connection gone; the session loop sees it too
                }
                std::thread::sleep(Duration::from_secs(1));
            }
        })
        .context("spawning heartbeat thread")?;

    let result = serve_session(&mut input, &output, true);
    stop.store(true, Ordering::Relaxed);
    let _ = stream.shutdown(std::net::Shutdown::Both);
    let _ = heartbeat.join();
    result
}

/// The `slleval serve-worker --listen <addr>` daemon: accept loop for a
/// per-host worker pool. Each accepted connection is one executor slot,
/// served on its own thread; `max_workers` (0 = unlimited) bounds the
/// pool — refused connections get a polite `init_error` frame instead of
/// a hang. Prints `listening on <addr>` to stdout once bound (with
/// `--listen host:0` the OS-assigned port is discoverable there).
pub fn serve_worker_main(listen: &str, max_workers: usize) -> Result<()> {
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding serve-worker listener on {listen}"))?;
    let addr = listener.local_addr().context("reading listener address")?;
    println!("listening on {addr}");
    std::io::stdout().flush().ok();
    eprintln!("slleval serve-worker: accepting executor connections on {addr}");

    let active = Arc::new(AtomicUsize::new(0));
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("warning: serve-worker accept failed: {e}");
                continue;
            }
        };
        if max_workers > 0 && active.load(Ordering::Relaxed) >= max_workers {
            let out: SharedWriter = Arc::new(Mutex::new(Box::new(stream)));
            let msg = Json::obj(vec![
                ("type", Json::str("init_error")),
                (
                    "error",
                    Json::str(&format!("host at capacity ({max_workers} workers)")),
                ),
            ]);
            let _ = write_frame_shared(&out, &msg);
            continue;
        }
        active.fetch_add(1, Ordering::Relaxed);
        let active = active.clone();
        let peer =
            stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "unknown".into());
        std::thread::Builder::new()
            .name("slleval-serve-conn".into())
            .spawn(move || {
                if let Err(e) = serve_connection(stream) {
                    eprintln!("serve-worker: session from {peer} ended with error: {e:#}");
                }
                active.fetch_sub(1, Ordering::Relaxed);
            })
            .context("spawning serve-worker connection thread")?;
    }
    Ok(())
}
