//! The `slleval worker` entry point: one out-of-process executor.
//!
//! Spawned by [`crate::sched::backend::ProcessBackend`] with stdin/stdout
//! pipes. Protocol (length-prefixed JSON frames, see
//! [`crate::sched::backend`]): a `hello` frame carries the serialized
//! [`TaskPlan`](crate::sched::plan::TaskPlan) + this worker's executor
//! id; the worker rebuilds its executor-local state from the plan
//! ([`PlanHost::from_plan`]), answers `ready` (or `init_error`), then
//! executes `task` frames one at a time until `shutdown` or EOF.
//!
//! All diagnostics go to stderr — stdout carries protocol frames only.
//!
//! The plan's [`WorkerFault`](crate::sched::plan::WorkerFault) hook makes
//! crash tests deterministic offline: the targeted executor
//! `std::process::abort()`s while executing its N-th task — a genuine
//! hard death (no unwinding, no cleanup, result never sent), exactly
//! what a `kill -9` or OOM kill looks like to the driver.

use std::io::Write;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::plan_exec::{PlanExecutor, PlanHost};
use crate::sched::backend::{read_frame, write_frame, PlanTaskRunner, TaskSpec};
use crate::sched::plan::TaskPlan;
use crate::util::json::Json;

/// Run the worker protocol over this process's stdin/stdout. Returns when
/// the driver shuts the pipe down; protocol violations are fatal (the
/// driver sees EOF and treats this executor as dead).
pub fn worker_main() -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut output = stdout.lock();

    let hello = read_frame(&mut input)?.context("expected hello frame on stdin")?;
    anyhow::ensure!(
        hello.str_or("type", "") == "hello",
        "protocol error: first frame must be hello, got '{}'",
        hello.str_or("type", "?")
    );
    let eid = hello.get("executor_id")?.as_usize()?;
    let batch_size = hello.usize_or("batch_size", 1).max(1);
    let plan = TaskPlan::from_json(hello.get("plan")?)
        .context("parsing task plan from hello frame")?;
    let fault = plan.fault.filter(|f| f.executor_id == eid);

    let mut executor = match PlanHost::from_plan(&plan)
        .and_then(|host| PlanExecutor::new(Arc::new(plan), eid, host))
    {
        Ok(e) => e,
        Err(e) => {
            let msg = Json::obj(vec![
                ("type", Json::str("init_error")),
                ("error", Json::str(&format!("{e:#}"))),
            ]);
            write_frame(&mut output, &msg)?;
            return Ok(());
        }
    };
    write_frame(&mut output, &Json::obj(vec![("type", Json::str("ready"))]))?;

    let mut received = 0usize;
    while let Some(frame) = read_frame(&mut input)? {
        match frame.str_or("type", "") {
            "task" => {
                let spec = TaskSpec::from_json(&frame).context("parsing task frame")?;
                received += 1;
                let result = executor.run(&spec, batch_size);
                // Deterministic hard death: computed but never reported —
                // the driver pays for exactly this in-flight task.
                if let Some(f) = fault {
                    if received == f.kill_after_tasks {
                        let _ = std::io::stderr().write_all(
                            format!(
                                "worker {eid}: fault injection — aborting on task {} \
                                 [{}, {})\n",
                                spec.task_id, spec.start, spec.end
                            )
                            .as_bytes(),
                        );
                        std::process::abort();
                    }
                }
                match result {
                    Ok(msg) => write_frame(&mut output, &msg.to_json())?,
                    Err(e) => {
                        let msg = Json::obj(vec![
                            ("type", Json::str("task_error")),
                            ("task_id", Json::num(spec.task_id as f64)),
                            ("error", Json::str(&format!("{e:#}"))),
                        ]);
                        write_frame(&mut output, &msg)?;
                    }
                }
            }
            "shutdown" => break,
            other => {
                eprintln!("worker {eid}: ignoring unknown frame type '{other}'");
            }
        }
    }
    // Clean exit: flush buffered cache writes so later runs/rescore see
    // what this worker paid for.
    executor.finish();
    Ok(())
}
