//! Two-model comparison with significance tests + effect sizes
//! (paper §4.3–§4.4, Table 2 selection).

use super::result::{ComparisonResult, EvalResult, MetricComparison};
use crate::config::EvalTask;
use crate::stats::{self, MetricScale};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Compare two completed evaluations metric by metric. Both must have been
/// run on the *same examples in the same order* (paired tests).
pub fn compare_results(
    a: &EvalResult,
    b: &EvalResult,
    task: &EvalTask,
) -> Result<ComparisonResult> {
    let mut comparisons = Vec::new();
    let mut rng = Rng::with_stream(task.statistics.seed, 0xCA);

    for report_a in &a.reports {
        let Some(report_b) = b.report(&report_a.name) else { continue };
        if report_a.values.len() != report_b.values.len() {
            bail!(
                "metric '{}' has mismatched example counts ({} vs {}) — \
                 comparisons must run on the same dataset",
                report_a.name,
                report_a.values.len(),
                report_b.values.len()
            );
        }
        // Paired values where BOTH models scored the example.
        let mut va = Vec::new();
        let mut vb = Vec::new();
        for (x, y) in report_a.values.iter().zip(&report_b.values) {
            if let (Some(x), Some(y)) = (x, y) {
                va.push(*x);
                vb.push(*y);
            }
        }
        if va.is_empty() {
            continue;
        }

        let scale = report_a.scale;
        let (choice, test) = stats::run_selected_test(
            scale,
            &va,
            &vb,
            task.statistics.permutations,
            &mut rng,
        );
        let odds = if scale == MetricScale::Binary {
            Some(stats::odds_ratio(&va, &vb))
        } else {
            None
        };
        comparisons.push(MetricComparison {
            metric: report_a.name.clone(),
            value_a: stats::describe::mean(&va),
            value_b: stats::describe::mean(&vb),
            test_choice: choice,
            test,
            cohens_d: stats::cohens_d(&va, &vb),
            hedges_g: stats::hedges_g(&va, &vb),
            odds_ratio: odds,
            n: va.len(),
        });
    }

    Ok(ComparisonResult {
        model_a: format!("{}/{}", a.provider, a.model),
        model_b: format!("{}/{}", b.provider, b.model),
        comparisons,
        alpha: task.statistics.alpha,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::EvalRunner;
    use crate::data::synth;
    use crate::providers::simulated::SimServiceConfig;
    use crate::ratelimit::VirtualClock;

    fn fast_runner() -> EvalRunner {
        let clock = VirtualClock::new();
        let mut r = EvalRunner::with_clock(clock);
        r.service_config = SimServiceConfig {
            server_error_rate: 0.0,
            unparseable_rate: 0.0,
            sleep_latency: false,
            ..Default::default()
        };
        r
    }

    #[test]
    fn strong_model_beats_weak_on_exact_match() {
        let runner = fast_runner();
        let df = synth::generate(
            250,
            21,
            synth::DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 },
        )
        .unwrap();

        let mut task_a = EvalTask::default();
        task_a.model.model_name = "gpt-4o".into();
        let mut task_b = task_a.clone();
        task_b.model.model_name = "gpt-3.5-turbo".into();

        let ra = runner.evaluate(&df, &task_a).unwrap();
        let rb = runner.evaluate(&df, &task_b).unwrap();
        let cmp = compare_results(&ra, &rb, &task_a).unwrap();

        let em = &cmp.comparisons[0];
        assert_eq!(em.metric, "exact_match");
        assert_eq!(em.test_choice, stats::TestChoice::McNemar);
        assert!(em.value_a > em.value_b, "a {} b {}", em.value_a, em.value_b);
        assert!(em.test.p_value < 0.05, "p {}", em.test.p_value);
        assert!(em.odds_ratio.is_some());
        assert!(!cmp.significant().is_empty());
    }

    #[test]
    fn identical_models_not_significant() {
        let runner = fast_runner();
        let df = synth::generate_default(120, 22);
        let task = EvalTask::default();
        let ra = runner.evaluate(&df, &task).unwrap();
        let rb = runner.evaluate(&df, &task).unwrap();
        let cmp = compare_results(&ra, &rb, &task).unwrap();
        // Deterministic engine: identical outputs → p = 1.
        for c in &cmp.comparisons {
            assert!(c.test.p_value > 0.99, "{}: p {}", c.metric, c.test.p_value);
            assert!((c.value_a - c.value_b).abs() < 1e-12);
        }
    }

    #[test]
    fn mismatched_datasets_rejected() {
        let runner = fast_runner();
        let task = EvalTask::default();
        let ra = runner.evaluate(&synth::generate_default(50, 1), &task).unwrap();
        let rb = runner.evaluate(&synth::generate_default(60, 1), &task).unwrap();
        assert!(compare_results(&ra, &rb, &task).is_err());
    }
}
