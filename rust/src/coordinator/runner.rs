//! The 4-stage evaluation runner (paper Figure 1):
//! prompt preparation → distributed inference → metric computation →
//! statistical aggregation.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::cached_engine::{CachedEngine, CallMeter};
use super::plan_exec;
use super::result::{EvalResult, InferenceStats, MetricValue};
use super::stopping::{MetricStopState, StoppingDriver};
use crate::cache::ResponseCache;
use crate::checkpoint::{fingerprint_sha256, RunCheckpoint, StageCheckpoint};
use crate::config::{BackendKind, CachePolicy, CiMethod, EvalTask, MetricConfig};
use crate::data::{DataFrame, Value};
use crate::engine::{BatchSlice, Progress};
use crate::metrics::{
    Example, JudgeBroker, MetricContext, MetricRegistry, MetricReport, MetricRequirements,
    ResolvedMetric, ScoreBatch,
};
use crate::sched::backend::{run_plan_wave, ProcessBackend};
use crate::sched::plan::{
    InferencePlan, MetricPlan, PlanEnv, PlanWork, StagePlan, TaskPlan, WorkerFault,
};
use crate::sched::remote::{heartbeat_timeout_from_env, RemoteBackend};
use crate::sched::{
    run_scheduled, run_scheduled_ext, run_scheduled_wave, TaskCheckpoint, TaskSink, WaveDecision,
    WaveGate,
};
use crate::providers::pipeline::PipelinedClient;
use crate::providers::retry::{infer_with_retry, RetryPolicy};
use crate::providers::simulated::{SimEngine, SimService, SimServiceConfig};
use crate::providers::tokenizer::estimate_request_tokens;
use crate::providers::{InferenceEngine, InferenceRequest};
use crate::ratelimit::{Clock, RealClock, TokenBucket};
use crate::runtime::SemanticRuntime;
use crate::stats::{self, MetricScale};
use crate::template::Template;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-example inference outcome (stage 2 output).
#[derive(Debug, Clone)]
pub struct RowInference {
    pub response: Option<String>,
    pub from_cache: bool,
    pub latency_ms: f64,
    pub cost_usd: f64,
    pub attempts: usize,
    pub error: Option<String>,
}

impl RowInference {
    /// Checkpoint-spill encoding (one JSON value per row; numbers use the
    /// shortest-round-trip float format, so restore is bit-exact).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "response",
                self.response.as_deref().map(Json::str).unwrap_or(Json::Null),
            ),
            ("from_cache", Json::Bool(self.from_cache)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("cost_usd", Json::num(self.cost_usd)),
            ("attempts", Json::num(self.attempts as f64)),
            ("error", self.error.as_deref().map(Json::str).unwrap_or(Json::Null)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<RowInference> {
        Ok(RowInference {
            response: v.opt("response").and_then(|r| r.as_str().ok()).map(String::from),
            from_cache: v.bool_or("from_cache", false),
            latency_ms: v.f64_or("latency_ms", 0.0),
            cost_usd: v.f64_or("cost_usd", 0.0),
            attempts: v.usize_or("attempts", 0),
            error: v.opt("error").and_then(|e| e.as_str().ok()).map(String::from),
        })
    }
}

/// Driver-side run lifecycle hooks — the `slleval serve` daemon's live
/// progress feed (see `crate::serve`). Called synchronously from the
/// run's driving thread: `inference_done` once stage 2 settles, then
/// `metric_done` after each metric's stage-3 scoring *and* stage-4
/// aggregation, so every partial estimate already carries its bootstrap
/// CI (never a bare point value). Default bodies make any hook opt-in.
pub trait RunObserver: Send + Sync {
    fn inference_done(&self, _stats: &InferenceStats) {}
    fn metric_done(&self, _index: usize, _total: usize, _value: &MetricValue) {}
    /// Adaptive stopping only: called after every wave look with the
    /// completed-prefix row count and each metric's certification state
    /// (mid-inference, from the scheduler's consulting thread — before
    /// `inference_done`).
    fn wave_done(&self, _wave: usize, _rows: usize, _stopping: &[MetricStopState]) {}
}

/// The evaluation coordinator. Owns the clock, provider services, cache,
/// and (optionally) the PJRT semantic runtime.
pub struct EvalRunner {
    pub clock: Arc<dyn Clock>,
    /// Provider endpoint simulation knobs (shared by all engines).
    pub service_config: SimServiceConfig,
    services: Mutex<std::collections::BTreeMap<String, Arc<SimService>>>,
    pub cache: Option<Arc<ResponseCache>>,
    pub runtime: Option<SemanticRuntime>,
    /// The metric registry every configured metric resolves through —
    /// starts with all built-ins; register custom metrics here
    /// ([`MetricRegistry::register_metric`]) before calling
    /// [`EvalRunner::evaluate`] / [`EvalRunner::rescore`].
    pub registry: MetricRegistry,
    /// Optional driver-side progress counter: the scheduler advances it as
    /// inference tasks complete, so long/streaming jobs can report real
    /// progress from another thread.
    pub progress: Option<Arc<Progress>>,
    /// Run-checkpoint store: when set, every inference/judging stage
    /// spills completed tasks crash-safely, and (in resume mode) restores
    /// completed ranges instead of re-executing them.
    pub checkpoint: Option<Arc<RunCheckpoint>>,
    /// Cooperative abort flag: set to `true` (from any thread — signal
    /// handler, UI, cost watchdog) to stop in-flight scheduled stages
    /// between batches. Checkpointed work survives the abort.
    pub abort: Option<Arc<AtomicBool>>,
    /// Path of the `slleval` binary used to spawn `--backend process`
    /// workers. `None` resolves via `SLLEVAL_WORKER_EXE`, then the
    /// current executable (correct when the driver *is* `slleval`).
    pub worker_exe: Option<std::path::PathBuf>,
    /// Deterministic executor-death injection for backend crash tests:
    /// the targeted executor dies hard while running its N-th task.
    pub worker_fault: Option<WorkerFault>,
    /// Run lifecycle observer (see [`RunObserver`]): the `slleval serve`
    /// daemon's live-progress feed. Called synchronously from the run's
    /// driving thread; `None` (the CLI one-shot path) costs nothing.
    pub observer: Option<Arc<dyn RunObserver>>,
    /// Persistent `--backend process` worker fleet: spawned by the first
    /// backend stage and kept alive across the run's later stages, which
    /// re-arm the live workers with a `plan` frame instead of respawning
    /// processes and re-shipping identical payloads.
    fleet: Mutex<Option<ProcessBackend>>,
}

impl EvalRunner {
    pub fn new() -> Self {
        Self::with_clock(Arc::new(RealClock::new()))
    }

    pub fn with_clock(clock: Arc<dyn Clock>) -> Self {
        Self {
            clock,
            service_config: SimServiceConfig::default(),
            services: Mutex::new(Default::default()),
            cache: None,
            runtime: None,
            registry: MetricRegistry::with_builtins(),
            progress: None,
            checkpoint: None,
            abort: None,
            worker_exe: None,
            worker_fault: None,
            observer: None,
            fleet: Mutex::new(None),
        }
    }

    /// Attach a driver-side progress counter (advanced by the scheduler as
    /// inference tasks complete).
    pub fn with_progress(mut self, progress: Arc<Progress>) -> Self {
        self.progress = Some(progress);
        self
    }

    pub fn with_cache(mut self, cache: ResponseCache) -> Self {
        self.cache = Some(Arc::new(cache));
        self
    }

    pub fn with_runtime(mut self, runtime: SemanticRuntime) -> Self {
        self.runtime = Some(runtime);
        self
    }

    /// Attach a run-checkpoint directory: `resume = false` starts a fresh
    /// run (the directory must not already hold one), `resume = true`
    /// reloads an interrupted run's manifest so completed task ranges are
    /// restored instead of re-executed.
    pub fn attach_checkpoint(&mut self, dir: &std::path::Path, resume: bool) -> Result<()> {
        let run =
            if resume { RunCheckpoint::resume(dir)? } else { RunCheckpoint::create(dir)? };
        self.checkpoint = Some(Arc::new(run));
        Ok(())
    }

    /// Attach a cooperative abort flag (see [`EvalRunner::abort`]).
    pub fn with_abort(mut self, abort: Arc<AtomicBool>) -> Self {
        self.abort = Some(abort);
        self
    }

    /// Open a content-addressed checkpoint stage (when a checkpoint
    /// directory is attached) and restore its completed ranges (when
    /// resuming). `parts` are the stage's exact inputs: their hash names
    /// the stage, so distinct inputs can never mix and a resume restores
    /// only byte-identical work. The returned digest is the stage's
    /// content address (empty when checkpointing is disabled — the
    /// corpus is hashed at most once, here).
    pub(crate) fn open_checkpoint_stage<T>(
        &self,
        kind: &str,
        parts: Vec<&str>,
        total_rows: usize,
        decode: &dyn Fn(&Json) -> Result<T>,
    ) -> Result<(Option<StageCheckpoint>, Vec<(usize, usize, Vec<T>)>, String)> {
        let Some(run) = &self.checkpoint else {
            return Ok((None, Vec::new(), String::new()));
        };
        let digest = fingerprint_sha256(parts);
        let fingerprint =
            Json::obj(vec![("kind", Json::str(kind)), ("sha256", Json::str(&digest))]);
        let stage = run.stage(&format!("{kind}-{}", &digest[..16]), &fingerprint, total_rows)?;
        let restored = if run.is_resume() { stage.restore(decode)? } else { Vec::new() };
        Ok((Some(stage), restored, digest))
    }

    /// Open (or reuse) the cache directory with the task's policy.
    pub fn open_cache(&mut self, dir: &std::path::Path, policy: CachePolicy) -> Result<()> {
        self.cache = if policy == CachePolicy::Disabled {
            None
        } else {
            Some(Arc::new(ResponseCache::open(dir, policy)?))
        };
        Ok(())
    }

    /// Shared provider endpoint handle (one per provider name). `pub(crate)`
    /// so sibling coordinator stages (pairwise judging) can build executor-
    /// local engines without capturing the non-`Sync` runner in closures.
    pub(crate) fn service(&self, provider: &str) -> Arc<SimService> {
        let mut services = self.services.lock().unwrap();
        services
            .entry(provider.to_string())
            .or_insert_with(|| {
                SimService::new(provider, self.service_config.clone(), self.clock.clone())
            })
            .clone()
    }

    fn make_engine(&self, provider: &str, model: &str) -> Result<SimEngine> {
        let mut e = SimEngine::new(self.service(provider), provider, model, self.clock.clone())?;
        e.initialize()?;
        Ok(e)
    }

    /// Build an initialized engine for an arbitrary model config (judge
    /// engines, pairwise comparison, ad-hoc calls).
    pub fn build_engine_for(&self, model: &crate::config::ModelConfig) -> Result<SimEngine> {
        self.make_engine(&model.provider, &model.model_name)
    }

    // ---------------------------------------------------------------- stage 1

    /// Render the prompt template over every row (distributed).
    pub fn prepare_prompts(&self, df: &DataFrame, task: &EvalTask) -> Result<Vec<String>> {
        let template = Template::parse(&task.data.prompt_template)
            .context("parsing prompt_template")?;
        let out = run_scheduled(
            df,
            task.executors,
            task.inference.batch_size,
            &task.scheduler,
            None,
            |_eid| Ok(template.clone()),
            |tpl, df, slice: BatchSlice| {
                slice
                    .indices()
                    .map(|i| tpl.render(&df.row(i).to_json()))
                    .collect::<Result<Vec<_>>>()
            },
        )?;
        Ok(out.rows)
    }

    // ---------------------------------------------------------------- stage 2

    /// Distributed inference with per-executor rate limiting, caching, and
    /// retry (paper §3.1–§3.2, Algorithm 1, Listing 1).
    pub fn run_inference(
        &self,
        prompts: &[String],
        task: &EvalTask,
    ) -> Result<(Vec<RowInference>, InferenceStats)> {
        self.run_inference_gated(prompts, task, None)
    }

    /// [`EvalRunner::run_inference`] plus an optional adaptive-stopping
    /// gate: with a [`StoppingDriver`], rows are issued in waves and the
    /// stage may settle early with an exact `[0, b)` prefix — the rows
    /// past `b` are recorded as deliberately skipped on the checkpoint
    /// stage so `--resume` and `rescore` never treat them as missing.
    /// `stopping: None` is byte-for-byte the classic all-at-once stage.
    pub(crate) fn run_inference_gated(
        &self,
        prompts: &[String],
        task: &EvalTask,
        stopping: Option<&StoppingDriver>,
    ) -> Result<(Vec<RowInference>, InferenceStats)> {
        // The task's data-skipping switch applies to every lookup this
        // stage makes, whichever backend executes it.
        if let Some(cache) = &self.cache {
            cache.set_skipping(task.inference.cache_skipping);
        }
        if task.backend != BackendKind::Thread {
            return self.run_inference_backend(prompts, task, stopping);
        }
        let t0 = self.clock.now();
        // lint:allow(determinism): reported wall_secs is wall-clock telemetry
        let wall0 = std::time::Instant::now();
        let df = DataFrame::from_columns(vec![(
            "prompt",
            prompts.iter().map(|p| Value::Str(p.clone())).collect(),
        )])?;

        let policy = RetryPolicy {
            max_retries: task.inference.max_retries,
            base_delay: task.inference.retry_delay,
            ..Default::default()
        };
        let cache = self.cache.clone();
        let clock = self.clock.clone();
        let inf = task.inference.clone();
        let model_cfg = task.model.clone();
        let executors = task.executors;
        // Pre-resolve the shared provider service: the executor closures
        // must not capture `self` (the runner holds the non-Sync PJRT
        // runtime).
        let service = self.service(&model_cfg.provider);
        let seed = task.statistics.seed;
        let progress = self.progress.as_deref();
        // API-call/cost accounting accumulated inside the UDF so it covers
        // EVERY attempt — including speculative duplicates and abandoned
        // task attempts whose row outputs the scheduler discards. The
        // provider bills those calls; the per-row fields below (cache
        // hits, failures, latencies) describe the winning rows only.
        // (api_calls, retries, cost_usd)
        let spend = Mutex::new((0u64, 0u64, 0.0f64));

        // Content-addressed checkpoint stage over the exact inference
        // inputs (prompts + model + sampling parameters): streaming
        // chunks and pairwise A/B passes get distinct stages for free.
        let temperature = format!("{:.6}", model_cfg.temperature);
        let max_tokens = model_cfg.max_tokens.to_string();
        let mut parts: Vec<&str> = vec![
            "inference",
            &model_cfg.provider,
            &model_cfg.model_name,
            &temperature,
            &max_tokens,
        ];
        parts.extend(prompts.iter().map(|p| p.as_str()));
        let (checkpoint_stage, restored, _) =
            self.open_checkpoint_stage("infer", parts, prompts.len(), &RowInference::from_json)?;
        let restored_spans: Vec<(usize, usize)> =
            restored.iter().map(|(s, e, _)| (*s, *e)).collect();

        // Abort plumbing. The externally attached handle is read-only from
        // here: a budget trip must not poison the caller's long-lived flag
        // (a reused runner would then abort every later stage at zero
        // spend). With a budget configured, the scheduler watches a
        // stage-local flag; the external handle (if any) is mirrored into
        // it once per row.
        let external_abort = self.abort.clone();
        let abort: Option<Arc<AtomicBool>> = match (&external_abort, inf.max_cost_usd) {
            (Some(flag), None) => Some(flag.clone()),
            (None, None) => None,
            (_, Some(_)) => Some(Arc::new(AtomicBool::new(false))),
        };
        let stage_abort = abort.clone();

        struct ExecState {
            /// Multiplexes up to `inference.concurrency` in-flight
            /// requests over slot engines sharing one token bucket.
            client: PipelinedClient,
        }

        let concurrency = inf.concurrency.max(1);
        // Per-executor peak in-flight occupancy, folded into the stats
        // after the job (indexed by the executor that ran each batch).
        let peaks: Vec<AtomicUsize> = (0..executors).map(|_| AtomicUsize::new(0)).collect();

        let encode_row = |r: &RowInference| r.to_json();
        let checkpoint = checkpoint_stage.as_ref().map(|stage| TaskCheckpoint {
            restored,
            sink: Some(TaskSink { stage, encode: &encode_row }),
        });

        let estimate =
            |req: &InferenceRequest| estimate_request_tokens(&req.prompt, req.max_tokens) as f64;

        // Per-completion spend accounting + cost-budget watchdog. Fires
        // as each request settles — the pipelined path invokes it from
        // the slot workers while the rest of the batch is still in
        // flight, so a budget trip raises the abort flag at per-request
        // granularity on both paths (the scheduler then winds the job
        // down between batches, keeping completed/checkpointed tasks).
        let account = |outcome: &crate::providers::retry::RetryOutcome| {
            let mut s = spend.lock().unwrap();
            s.0 += outcome.attempts as u64;
            if let Ok(resp) = &outcome.result {
                s.1 += (outcome.attempts - 1) as u64;
                s.2 += resp.cost_usd;
                if let (Some(budget), Some(flag)) = (inf.max_cost_usd, &stage_abort) {
                    if s.2 > budget {
                        flag.store(true, Ordering::Relaxed);
                    }
                }
            }
        };

        // Cache lookup / row assembly: the shared single implementations
        // ([`plan_exec::cache_lookup`] / [`plan_exec::assemble`]), so the
        // closure scheduler and the plan-executor backends cannot drift.
        let assemble = |outcome: crate::providers::retry::RetryOutcome,
                        prompt: &str|
         -> Result<RowInference> {
            plan_exec::assemble(&cache, &model_cfg, inf.cache_policy, outcome, prompt)
        };
        let cache_lookup = |prompt: &str, i: usize| -> Result<Option<RowInference>> {
            plan_exec::cache_lookup(&cache, &model_cfg, inf.cache_policy, prompt, i)
        };

        // Wave gate (adaptive stopping): the decide closure only ever
        // runs when a driver is present — `gate` is `None` otherwise,
        // which is the ungated scheduler, byte for byte.
        let decide = |wave: usize, prefix: &[&RowInference]| -> Result<WaveDecision> {
            match stopping {
                Some(d) => d.decide_rows(wave, prefix),
                None => Ok(WaveDecision::Continue),
            }
        };
        let gate = stopping.map(|d| WaveGate {
            first: d.first_wave_rows(),
            step: d.wave_step(),
            decide: &decide,
        });

        let out = run_scheduled_wave(
            &df,
            executors,
            inf.batch_size,
            &task.scheduler,
            progress,
            checkpoint,
            abort.as_deref(),
            gate,
            |eid| {
                // One engine per concurrency slot (the paper's
                // `_ENGINE_CACHE`, widened): slot 0 at concurrency 1 is
                // exactly the old single engine, including its rng stream
                // and call sequence.
                let mut slots: Vec<Box<dyn InferenceEngine>> = Vec::with_capacity(concurrency);
                for _ in 0..concurrency {
                    let mut engine = SimEngine::new(
                        service.clone(),
                        &model_cfg.provider,
                        &model_cfg.model_name,
                        clock.clone(),
                    )?;
                    engine.initialize()?;
                    slots.push(Box::new(engine));
                }
                let rngs = (0..concurrency)
                    .map(|s| Rng::with_stream(seed, eid as u64 ^ ((s as u64) << 32)))
                    .collect();
                let bucket = TokenBucket::per_executor(
                    inf.rate_limit_rpm,
                    inf.rate_limit_tpm,
                    executors,
                    clock.as_ref(),
                );
                Ok(ExecState {
                    client: PipelinedClient::new(
                        slots,
                        rngs,
                        policy,
                        Some(bucket),
                        clock.clone(),
                    ),
                })
            },
            |state, df, slice| {
                if state.client.concurrency() == 1 {
                    // Sequential path — the exact pre-pipeline per-row
                    // loop (cache lookup, blocking admission, retry,
                    // cache write interleaved), bit-identical to the old
                    // hot path.
                    let (engine, rng, bucket) = state.client.sequential_parts();
                    let bucket = bucket.expect("inference client always has a bucket");
                    let mut rows = Vec::with_capacity(slice.len());
                    for i in slice.indices() {
                        // Mirror the caller's abort handle into the stage
                        // flag (no-op when they are the same flag).
                        if let (Some(ext), Some(local)) = (&external_abort, &stage_abort) {
                            if ext.load(Ordering::Relaxed) {
                                local.store(true, Ordering::Relaxed);
                            }
                        }
                        let prompt = df.row(i).str("prompt");
                        if let Some(hit) = cache_lookup(prompt, i)? {
                            rows.push(hit);
                            continue;
                        }

                        // Algorithm 1: acquire request + token budget.
                        let mut req = InferenceRequest::new(prompt);
                        req.max_tokens = model_cfg.max_tokens;
                        req.temperature = model_cfg.temperature;
                        bucket.acquire(estimate(&req), clock.as_ref());
                        let outcome =
                            infer_with_retry(engine, &req, &policy, clock.as_ref(), rng);
                        peaks[slice.executor_id].fetch_max(1, Ordering::Relaxed);
                        account(&outcome);
                        rows.push(assemble(outcome, prompt)?);
                    }
                    return Ok(rows);
                }

                // Pipelined path: resolve the whole batch's cache hits
                // first (clock-free), then drive every miss through the
                // slot pipeline so their provider latencies overlap.
                if let (Some(ext), Some(local)) = (&external_abort, &stage_abort) {
                    if ext.load(Ordering::Relaxed) {
                        local.store(true, Ordering::Relaxed);
                    }
                }
                let mut rows: Vec<Option<RowInference>> =
                    (0..slice.len()).map(|_| None).collect();
                let mut miss_at: Vec<usize> = Vec::new();
                let mut miss_reqs: Vec<InferenceRequest> = Vec::new();
                for (k, i) in slice.indices().enumerate() {
                    let prompt = df.row(i).str("prompt");
                    if let Some(hit) = cache_lookup(prompt, i)? {
                        rows[k] = Some(hit);
                        continue;
                    }
                    let mut req = InferenceRequest::new(prompt);
                    req.max_tokens = model_cfg.max_tokens;
                    req.temperature = model_cfg.temperature;
                    miss_at.push(k);
                    miss_reqs.push(req);
                }
                let batch = state.client.run_batch(&miss_reqs, &estimate, Some(&account))?;
                peaks[slice.executor_id]
                    .fetch_max(batch.stats.peak_in_flight, Ordering::Relaxed);
                for (j, outcome) in batch.outcomes.into_iter().enumerate() {
                    rows[miss_at[j]] = Some(assemble(outcome, &miss_reqs[j].prompt)?);
                }
                Ok(rows.into_iter().map(|r| r.expect("every row settled")).collect())
            },
        )?;

        // Virtual clocks may not advance when latency sleeps are disabled;
        // fall back to real wall time so throughput stays meaningful.
        let wall = (self.clock.now() - t0).max(wall0.elapsed().as_secs_f64()).max(1e-9);
        let rows = out.rows;
        // A gate-settled stage covered only `[0, rows.len())`: record the
        // untouched suffix as deliberately skipped so a later `--resume`
        // or `rescore` of this stage never treats saved rows as missing.
        if rows.len() < prompts.len() {
            if let Some(stage) = &checkpoint_stage {
                stage.record_skipped(&[(rows.len(), prompts.len())])?;
            }
        }
        // Fold per-executor pipeline occupancy into the executor stats.
        let mut exec_stats = out.executors;
        for e in &mut exec_stats {
            e.peak_in_flight = peaks[e.executor_id].load(Ordering::Relaxed);
        }
        let mut stats = InferenceStats {
            examples: rows.len(),
            wall_secs: wall,
            throughput_per_min: rows.len() as f64 / wall * 60.0,
            sched: out.sched,
            timeline: out.timeline,
            concurrency,
            peak_in_flight: exec_stats.iter().map(|e| e.peak_in_flight).max().unwrap_or(0),
            executors: exec_stats,
            ..Default::default()
        };
        // True provider spend over every attempt (speculative duplicates
        // included); per-row accounting below covers the winning rows.
        let (api_calls, retries, cost_usd) = *spend.lock().unwrap();
        stats.api_calls = api_calls;
        stats.retries = retries;
        stats.total_cost_usd = cost_usd;
        // Per-row accounting describes THIS run's fresh work only: rows
        // restored from the checkpoint are reported via
        // `sched.restored_rows`, and their cache hits / latencies belong
        // to the run that paid for them (api_calls/cost above are
        // fresh-only for the same reason).
        let in_restored = |i: usize| restored_spans.iter().any(|&(s, e)| i >= s && i < e);
        let mut latencies: Vec<f64> = Vec::new();
        for (i, r) in rows.iter().enumerate() {
            if in_restored(i) {
                continue;
            }
            if r.from_cache {
                stats.cache_hits += 1;
            } else if r.response.is_some() {
                stats.cache_misses += 1;
                latencies.push(r.latency_ms);
            } else {
                stats.cache_misses += 1;
                stats.failed += 1;
            }
        }
        if !latencies.is_empty() {
            latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
            stats.latency_p50_ms = stats::describe::quantile_sorted(&latencies, 0.5);
            stats.latency_p99_ms = stats::describe::quantile_sorted(&latencies, 0.99);
        }
        Ok((rows, stats))
    }

    // ----------------------------------------------------- executor backends

    /// Execution environment shipped inside serializable task plans so an
    /// out-of-process worker can rebuild this runner's engines.
    pub(crate) fn plan_env(&self, cache_policy: CachePolicy) -> PlanEnv {
        PlanEnv {
            service: self.service_config.clone(),
            virtual_clock: self.clock.is_virtual(),
            cache_dir: self.cache.as_ref().map(|c| c.dir().display().to_string()),
            cache_policy,
        }
    }

    /// Run one serializable plan on the task's configured non-thread
    /// backend. `--backend process` reuses the run's persistent worker
    /// fleet: live workers are re-armed over their existing pipes, and
    /// the fleet survives `run_plan`'s shutdown call for the next stage.
    /// `--backend remote` connects to the task's `serve-worker` hosts
    /// for the duration of the stage; `stage` is the driver-side
    /// checkpoint that uploaded spill frames are recorded into (remote
    /// workers share no filesystem with the driver). `gate` plugs the
    /// adaptive-stopping wave rule into the backend driver loop — the
    /// gating is entirely driver-side, so thread, process, and remote
    /// backends share one stopping implementation.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_plan_on_backend(
        &self,
        task: &EvalTask,
        plan: &TaskPlan,
        total_rows: usize,
        batch_size: usize,
        restored: Vec<(usize, usize, Vec<Json>)>,
        progress: Option<&Progress>,
        max_cost_usd: Option<f64>,
        stage: Option<Arc<StageCheckpoint>>,
        gate: Option<&WaveGate<'_, Json>>,
    ) -> Result<crate::sched::backend::PlanOutput> {
        match task.backend {
            BackendKind::Process => {
                let mut fleet = self.fleet.lock().unwrap();
                match fleet.as_mut() {
                    Some(backend) if backend.executors() == task.executors => {
                        backend.reset_plan(plan, batch_size);
                    }
                    _ => {
                        // First backend stage of the run (or the executor
                        // count changed): spawn a fresh persistent fleet.
                        let mut backend = ProcessBackend::new(
                            plan,
                            task.executors,
                            batch_size,
                            self.worker_exe.clone(),
                        )?;
                        backend.set_keep_alive(true);
                        *fleet = Some(backend);
                    }
                }
                let backend = fleet.as_mut().expect("fleet populated above");
                run_plan_wave(
                    total_rows,
                    task.executors,
                    &task.scheduler,
                    backend,
                    progress,
                    restored,
                    self.abort.as_deref(),
                    max_cost_usd,
                    gate,
                )
            }
            BackendKind::Remote => {
                let mut backend = RemoteBackend::new(
                    plan,
                    task.executors,
                    batch_size,
                    task.hosts.clone(),
                    heartbeat_timeout_from_env(),
                    stage,
                )?;
                run_plan_wave(
                    total_rows,
                    task.executors,
                    &task.scheduler,
                    &mut backend,
                    progress,
                    restored,
                    self.abort.as_deref(),
                    max_cost_usd,
                    gate,
                )
            }
            BackendKind::Thread => {
                bail!("run_plan_on_backend called with the thread backend")
            }
        }
    }

    /// `--backend process` / `--backend remote` inference: the same
    /// stage, expressed as a serializable [`TaskPlan`] and executed by
    /// crash-isolated `slleval worker` processes (or remote
    /// `serve-worker` hosts) through the generic backend scheduler.
    /// The checkpoint stage is content-addressed identically to the
    /// thread path, so thread, process, and remote runs restore each
    /// other's spilled work.
    fn run_inference_backend(
        &self,
        prompts: &[String],
        task: &EvalTask,
        stopping: Option<&StoppingDriver>,
    ) -> Result<(Vec<RowInference>, InferenceStats)> {
        let t0 = self.clock.now();
        // lint:allow(determinism): reported wall_secs is wall-clock telemetry
        let wall0 = std::time::Instant::now();
        let inf = task.inference.clone();
        let model_cfg = task.model.clone();

        let temperature = format!("{:.6}", model_cfg.temperature);
        let max_tokens = model_cfg.max_tokens.to_string();
        let mut parts: Vec<&str> = vec![
            "inference",
            &model_cfg.provider,
            &model_cfg.model_name,
            &temperature,
            &max_tokens,
        ];
        parts.extend(prompts.iter().map(|p| p.as_str()));
        let decode_raw = |v: &Json| Ok(v.clone());
        let (stage, restored, digest) =
            self.open_checkpoint_stage("infer", parts, prompts.len(), &decode_raw)?;
        // Arc so the remote backend's reader threads can record uploaded
        // spill frames into the same driver-side stage.
        let stage = stage.map(Arc::new);
        let restored_spans: Vec<(usize, usize)> =
            restored.iter().map(|(s, e, _)| (*s, *e)).collect();

        let plan = TaskPlan {
            work: PlanWork::Inference(InferencePlan {
                model: model_cfg,
                inference: inf.clone(),
                executors: task.executors,
                seed: task.statistics.seed,
                prompts: prompts.to_vec(),
            }),
            env: self.plan_env(inf.cache_policy),
            stage: stage.as_ref().map(|s| StagePlan {
                dir: s.dir().display().to_string(),
                fingerprint: digest,
            }),
            fault: self.worker_fault,
        };
        // Backend rows cross the driver loop as raw checkpoint-encoded
        // JSON; the gate decodes the prefix before consulting the rule.
        let decide = |wave: usize, prefix: &[&Json]| -> Result<WaveDecision> {
            match stopping {
                Some(d) => d.decide_json(wave, prefix),
                None => Ok(WaveDecision::Continue),
            }
        };
        let gate = stopping.map(|d| WaveGate {
            first: d.first_wave_rows(),
            step: d.wave_step(),
            decide: &decide,
        });
        let out = self.run_plan_on_backend(
            task,
            &plan,
            prompts.len(),
            inf.batch_size,
            restored,
            self.progress.as_deref(),
            inf.max_cost_usd,
            stage.clone(),
            gate.as_ref(),
        )?;
        if out.rows.len() < prompts.len() {
            if let Some(stage) = &stage {
                stage.record_skipped(&[(out.rows.len(), prompts.len())])?;
            }
        }
        self.backend_inference_stats(out, &restored_spans, t0, wall0, inf.concurrency)
    }

    /// Decode a backend job's raw rows into [`RowInference`]s and build
    /// the same [`InferenceStats`] the thread path reports (spend comes
    /// from the executors' own per-task accounting).
    fn backend_inference_stats(
        &self,
        out: crate::sched::backend::PlanOutput,
        restored_spans: &[(usize, usize)],
        t0: f64,
        wall0: std::time::Instant,
        concurrency: usize,
    ) -> Result<(Vec<RowInference>, InferenceStats)> {
        let rows =
            out.rows.iter().map(RowInference::from_json).collect::<Result<Vec<_>>>()?;
        // Worker processes sleep latency on their own clocks, so the
        // driver's (possibly virtual) clock may not advance: fall back to
        // real wall time.
        let wall = (self.clock.now() - t0).max(wall0.elapsed().as_secs_f64()).max(1e-9);
        let mut stats = InferenceStats {
            examples: rows.len(),
            wall_secs: wall,
            throughput_per_min: rows.len() as f64 / wall * 60.0,
            sched: out.sched,
            timeline: out.timeline,
            concurrency,
            peak_in_flight: out.peak_in_flight,
            executors: out.executors,
            api_calls: out.api_calls,
            retries: out.retries,
            total_cost_usd: out.cost_usd,
            ..Default::default()
        };
        let in_restored = |i: usize| restored_spans.iter().any(|&(s, e)| i >= s && i < e);
        let mut latencies: Vec<f64> = Vec::new();
        for (i, r) in rows.iter().enumerate() {
            if in_restored(i) {
                continue;
            }
            if r.from_cache {
                stats.cache_hits += 1;
            } else if r.response.is_some() {
                stats.cache_misses += 1;
                latencies.push(r.latency_ms);
            } else {
                stats.cache_misses += 1;
                stats.failed += 1;
            }
        }
        if !latencies.is_empty() {
            latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
            stats.latency_p50_ms = stats::describe::quantile_sorted(&latencies, 0.5);
            stats.latency_p99_ms = stats::describe::quantile_sorted(&latencies, 0.99);
        }
        Ok((rows, stats))
    }

    /// Pure-metric scoring as a serializable plan on the configured
    /// executor backend. Only registry built-ins are eligible (a custom
    /// metric object cannot cross a process boundary).
    fn score_pure_backend(
        &self,
        cfg: &MetricConfig,
        examples: &[Example],
        task: &EvalTask,
    ) -> Result<ScoreBatch> {
        let plan = TaskPlan {
            work: PlanWork::MetricScore(MetricPlan {
                metric: cfg.clone(),
                examples: examples.to_vec(),
            }),
            env: self.plan_env(CachePolicy::Disabled),
            stage: None,
            // Crash injection targets the inference stage only; metric
            // scoring reuses executor ids and would otherwise re-fire.
            fault: None,
        };
        let out = self.run_plan_on_backend(
            task,
            &plan,
            examples.len(),
            task.inference.batch_size,
            Vec::new(),
            None,
            None,
            None,
            None,
        )?;
        // The metric stage (like its thread-path counterpart) reports no
        // scheduler stats in the result; don't let a recovered worker
        // death pass entirely silently.
        if out.sched.executor_deaths > 0 {
            eprintln!(
                "warning: {} executor death(s) while scoring metric '{}' \
                 (recovered by retry; not counted in the run's scheduler stats)",
                out.sched.executor_deaths,
                cfg.name
            );
        }
        Ok(ScoreBatch::scored(out.rows.into_iter().map(|v| v.as_f64().ok()).collect()))
    }

    // ---------------------------------------------------------------- stage 3

    /// Assemble per-example contexts from the source frame + responses.
    pub fn build_examples(
        &self,
        df: &DataFrame,
        task: &EvalTask,
        prompts: &[String],
        inference: &[RowInference],
    ) -> Vec<Example> {
        (0..df.len())
            .map(|i| {
                let row = df.row(i);
                Example {
                    prompt: prompts[i].clone(),
                    response: inference[i].response.clone().unwrap_or_default(),
                    reference: row.str(&task.data.reference_column).to_string(),
                    question: row.str(&task.data.question_column).to_string(),
                    context: row
                        .get(&task.data.context_column)
                        .and_then(|v| v.as_str_list())
                        .map(|l| l.to_vec())
                        .unwrap_or_default(),
                    gold_position: row
                        .get("gold_position")
                        .and_then(|v| v.as_f64())
                        .map(|v| v as i64)
                        .unwrap_or(-1),
                }
            })
            .collect()
    }

    /// Compute one configured metric over all examples: resolve through
    /// the registry, then score. Examples whose inference failed score
    /// `None`. (Callers computing several metrics should resolve once via
    /// [`MetricRegistry::resolve_task`] and use
    /// [`EvalRunner::compute_resolved`] with a shared meter.)
    pub fn compute_metric(
        &self,
        config: &MetricConfig,
        examples: &[Example],
        task: &EvalTask,
        failed: &[bool],
    ) -> Result<MetricReport> {
        let metric = self.registry.resolve(config)?;
        self.compute_resolved(&metric, examples, task, failed, &Arc::new(CallMeter::default()))
    }

    /// Score one resolved metric. Dispatch is driven by the metric's
    /// declared requirements, not its name:
    ///
    /// - `Pure` metrics run as scheduler tasks across executors (the
    ///   distributed metric stage — rescoring large frames scales like
    ///   inference does);
    /// - `Runtime` metrics batch on the driver through the PJRT runtime;
    /// - `Judge` metrics get a cache-wrapped, metered judge engine.
    pub fn compute_resolved(
        &self,
        metric: &ResolvedMetric,
        examples: &[Example],
        task: &EvalTask,
        failed: &[bool],
        meter: &Arc<CallMeter>,
    ) -> Result<MetricReport> {
        let out = match metric.requirements() {
            MetricRequirements::Pure => {
                // Process/remote backends: registry built-ins score as a
                // serializable plan on the executor backend; custom
                // metric objects cannot cross a process boundary, so they
                // fall back to the in-process distributed path.
                let backend_cfg = (task.backend != BackendKind::Thread)
                    .then(|| {
                        task.metrics
                            .iter()
                            .find(|m| m.name == metric.name() && m.metric_type != "custom")
                    })
                    .flatten();
                if let Some(cfg) = backend_cfg {
                    self.score_pure_backend(cfg, examples, task)?
                } else {
                    let df = DataFrame::from_columns(vec![(
                        "i",
                        (0..examples.len() as i64).map(Value::Int).collect::<Vec<_>>(),
                    )])?;
                    let m = metric.clone();
                    let sched_out = run_scheduled(
                        &df,
                        task.executors,
                        task.inference.batch_size,
                        &task.scheduler,
                        None,
                        |_| Ok(()),
                        |_, _df, slice| {
                            let batch = m.score_batch(
                                &MetricContext::detached(),
                                &examples[slice.indices()],
                            )?;
                            plan_exec::validate_pure_batch(m.name(), &batch, slice.len())?;
                            Ok(batch.values)
                        },
                    )?;
                    ScoreBatch::scored(sched_out.rows)
                }
            }
            MetricRequirements::Runtime => {
                let ctx = MetricContext {
                    runtime: self.runtime.as_ref(),
                    judge: None,
                    default_provider: &task.model.provider,
                    default_model: &task.model.model_name,
                };
                metric.score_batch(&ctx, examples)?
            }
            MetricRequirements::Judge => {
                let broker = RunnerJudgeBroker { runner: self, meter: meter.clone() };
                let ctx = MetricContext {
                    runtime: self.runtime.as_ref(),
                    judge: Some(&broker),
                    default_provider: &task.model.provider,
                    default_model: &task.model.model_name,
                };
                metric.score_batch(&ctx, examples)?
            }
        };
        let mut values = out.values;
        anyhow::ensure!(
            values.len() == examples.len(),
            "metric '{}' returned {} values for {} examples",
            metric.name(),
            values.len(),
            examples.len()
        );
        for (v, &f) in values.iter_mut().zip(failed) {
            if f {
                *v = None;
            }
        }
        Ok(MetricReport {
            name: metric.name().to_string(),
            values,
            scale: metric.scale(),
            unparseable: out.unparseable,
        })
    }

    // ---------------------------------------------------------------- stage 4

    /// Aggregate one metric report into a point estimate + CI.
    pub fn aggregate(&self, report: &MetricReport, task: &EvalTask) -> MetricValue {
        let scored = report.scored();
        let s = &task.statistics;
        let mut rng = Rng::with_stream(s.seed, 0xC1);
        let point = stats::describe::mean(&scored);

        let ci = if scored.is_empty() {
            stats::ConfidenceInterval {
                point: f64::NAN,
                lo: f64::NAN,
                hi: f64::NAN,
                level: s.confidence_level,
                method: "none",
            }
        } else {
            match s.ci_method {
                CiMethod::Analytic => {
                    if report.scale == MetricScale::Binary {
                        let successes = scored.iter().filter(|&&v| v >= 0.5).count() as u64;
                        stats::wilson_interval(successes, scored.len() as u64, s.confidence_level)
                    } else {
                        stats::t_interval(&scored, s.confidence_level)
                    }
                }
                CiMethod::Percentile => {
                    if let Some(boots) = self.device_bootstrap(&scored, s) {
                        percentile_from_boots(point, boots, s.confidence_level)
                    } else {
                        stats::percentile_bootstrap(
                            &scored,
                            stats::describe::mean,
                            s.confidence_level,
                            s.bootstrap_iterations,
                            &mut rng,
                        )
                    }
                }
                CiMethod::Bca => stats::bca_bootstrap(
                    &scored,
                    stats::describe::mean,
                    s.confidence_level,
                    s.bootstrap_iterations,
                    &mut rng,
                ),
            }
        };

        MetricValue {
            name: report.name.clone(),
            value: point,
            ci,
            n: report.n_scored(),
            n_failed: report.n_failed(),
            unparseable: report.unparseable,
            // Stamped afterwards by the adaptive-stopping driver (None =
            // stopping disabled, keeping result JSON byte-identical).
            stopped_at_wave: None,
            certified: None,
        }
    }

    /// Device (XLA) bootstrap when enabled and shapes fit.
    fn device_bootstrap(
        &self,
        scored: &[f64],
        s: &crate::config::StatisticsConfig,
    ) -> Option<Vec<f64>> {
        if !s.use_device_bootstrap {
            return None;
        }
        let runtime = self.runtime.as_ref()?;
        if s.bootstrap_iterations != runtime.manifest.bootstrap.resamples {
            return None;
        }
        let mut rng = Rng::with_stream(s.seed, 0xDE);
        runtime.bootstrap_means(scored, &mut rng).ok().flatten()
    }

    // ---------------------------------------------------------------- driver

    /// Full 4-stage evaluation (the paper's `runner.evaluate(df, task)`).
    pub fn evaluate(&self, df: &DataFrame, task: &EvalTask) -> Result<EvalResult> {
        task.validate()?;
        // Load-time metric resolution: a typo'd or unregistered metric
        // name fails here, before any inference spend.
        let resolved = self.registry.resolve_task(task)?;
        let t0 = self.clock.now();

        // Stage 1: prompt preparation.
        let prompts = self.prepare_prompts(df, task)?;

        // Adaptive stopping: the wave loop replaces the all-at-once
        // stage-2 dispatch (absent `stopping` block = this classic path,
        // bit for bit).
        if task.stopping.is_some() {
            return self.evaluate_stopping(df, task, &resolved, prompts, t0);
        }

        // Stage 2: distributed inference.
        let (inference_rows, inf_stats) = self.run_inference(&prompts, task)?;

        self.score_and_aggregate(df, task, &resolved, prompts, inference_rows, inf_stats, t0)
    }

    /// The adaptive-stopping evaluation path: inference runs in waves
    /// through a [`StoppingDriver`] gate, may settle early with an exact
    /// `[0, n_eval)` prefix, and stages 3–4 then score/aggregate that
    /// prefix only. Every metric's final [`MetricValue`] is stamped with
    /// its certification state. Note the stage fingerprints still cover
    /// the FULL prompt list — where a run stops never changes its
    /// content address, so resumes and rescores line up exactly.
    fn evaluate_stopping(
        &self,
        df: &DataFrame,
        task: &EvalTask,
        resolved: &[ResolvedMetric],
        prompts: Vec<String>,
        t0: f64,
    ) -> Result<EvalResult> {
        // Response-less skeleton: the driver fills responses per wave, so
        // prompt/reference assembly happens once, not once per look.
        let blank: Vec<RowInference> = (0..df.len())
            .map(|_| RowInference {
                response: None,
                from_cache: false,
                latency_ms: 0.0,
                cost_usd: 0.0,
                attempts: 0,
                error: None,
            })
            .collect();
        let skeleton = self.build_examples(df, task, &prompts, &blank);
        let driver =
            StoppingDriver::new(task, resolved, skeleton, self.observer.clone())?;

        let (inference_rows, inf_stats) =
            self.run_inference_gated(&prompts, task, Some(&driver))?;

        // An early-settled stage returns the exact certified prefix:
        // stages 3–4 run over that prefix of the frame.
        let n_eval = inference_rows.len();
        let prefix_df;
        let (df_eval, prompts_eval) = if n_eval < df.len() {
            prefix_df = df.take(&(0..n_eval).collect::<Vec<_>>())?;
            (&prefix_df, prompts[..n_eval].to_vec())
        } else {
            (df, prompts)
        };
        let mut result = self.score_and_aggregate(
            df_eval,
            task,
            resolved,
            prompts_eval,
            inference_rows,
            inf_stats,
            t0,
        )?;
        // Stamp each metric's certification state (driver order is
        // resolution order, which is `result.metrics` order).
        for (value, state) in result.metrics.iter_mut().zip(driver.states()) {
            value.stopped_at_wave = state.stopped_at_wave;
            value.certified = Some(state.certified);
        }
        Ok(result)
    }

    /// Stages 3–4 over already-obtained responses, shared by
    /// [`EvalRunner::evaluate`] and [`EvalRunner::rescore`].
    #[allow(clippy::too_many_arguments)]
    fn score_and_aggregate(
        &self,
        df: &DataFrame,
        task: &EvalTask,
        resolved: &[ResolvedMetric],
        prompts: Vec<String>,
        inference_rows: Vec<RowInference>,
        inf_stats: InferenceStats,
        t0: f64,
    ) -> Result<EvalResult> {
        let failed: Vec<bool> = inference_rows.iter().map(|r| r.response.is_none()).collect();
        let failed_examples: Vec<usize> =
            failed.iter().enumerate().filter(|(_, &f)| f).map(|(i, _)| i).collect();

        // Stages 3+4, interleaved per metric (one shared judge-call
        // meter): compute a metric's report, aggregate it immediately —
        // `aggregate` seeds a fresh bootstrap rng per call, so this is
        // bit-identical to the former compute-all-then-aggregate-all
        // split — and surface it through the observer, so a partial
        // estimate with its CI exists as soon as each metric settles.
        // A cooperative abort lands between metrics: already-settled
        // estimates (and cached/checkpointed work) survive.
        let examples = self.build_examples(df, task, &prompts, &inference_rows);
        let meter = Arc::new(CallMeter::default());
        if let Some(observer) = &self.observer {
            observer.inference_done(&inf_stats);
        }
        let mut reports = Vec::with_capacity(resolved.len());
        let mut metrics = Vec::with_capacity(resolved.len());
        for (index, metric) in resolved.iter().enumerate() {
            if let Some(flag) = &self.abort {
                if flag.load(std::sync::atomic::Ordering::Relaxed) {
                    anyhow::bail!(
                        "run aborted before metric '{}' ({index}/{} metrics complete)",
                        metric.name(),
                        resolved.len()
                    );
                }
            }
            let report = self.compute_resolved(metric, &examples, task, &failed, &meter)?;
            let value = self.aggregate(&report, task);
            if let Some(observer) = &self.observer {
                observer.metric_done(index, resolved.len(), &value);
            }
            reports.push(report);
            metrics.push(value);
        }

        // Flush cache writes so a following replay/rescore run sees them.
        if let Some(cache) = &self.cache {
            cache.flush()?;
        }

        Ok(EvalResult {
            task_id: task.task_id.clone(),
            provider: task.model.provider.clone(),
            model: task.model.model_name.clone(),
            metrics,
            reports,
            inference: inf_stats,
            metric_calls: meter.stats(),
            failed_examples,
            wall_secs: self.clock.now() - t0,
        })
    }

    // ---------------------------------------------------------------- rescore

    /// Score-from-cache pipeline: recompute any metric set over responses
    /// rehydrated from the response cache and/or an attached run
    /// checkpoint — the inference stage is replaced by lookups and
    /// **never calls a provider**. This is the paper's "iterate on metric
    /// definitions without re-running inference" claim as a first-class
    /// stage: pure metrics score as distributed scheduler tasks, judge
    /// metrics flow through the (metered) cache, and aggregation runs
    /// fresh bootstrap CIs with the task's seed, so a rescore of an
    /// unchanged metric is bit-identical to the live run.
    ///
    /// `allow_missing`: score rows with no cached/checkpointed response
    /// as failed examples instead of erroring (useful when the live run
    /// itself had failed rows, which never reach the cache).
    pub fn rescore(
        &self,
        df: &DataFrame,
        task: &EvalTask,
        allow_missing: bool,
    ) -> Result<EvalResult> {
        task.validate()?;
        let resolved = self.registry.resolve_task(task)?;
        let t0 = self.clock.now();

        let prompts = self.prepare_prompts(df, task)?;
        let (rows, stats) = self.rehydrate_responses(&prompts, task, allow_missing)?;
        // A checkpoint from a stopping-settled run rehydrates only its
        // evaluated prefix (the saved suffix was deliberately never run):
        // rescore scores exactly those rows, with no missing-row errors
        // for the rest.
        if rows.len() < df.len() {
            let prefix_df = df.take(&(0..rows.len()).collect::<Vec<_>>())?;
            let prompts_eval = prompts[..rows.len()].to_vec();
            return self.score_and_aggregate(
                &prefix_df, task, &resolved, prompts_eval, rows, stats, t0,
            );
        }
        self.score_and_aggregate(df, task, &resolved, prompts, rows, stats, t0)
    }

    /// Rehydrate per-row responses without inference: ranges recorded in
    /// an attached run checkpoint restore directly (same content-addressed
    /// stage key as [`EvalRunner::run_inference`]); everything else is a
    /// distributed cache lookup.
    fn rehydrate_responses(
        &self,
        prompts: &[String],
        task: &EvalTask,
        allow_missing: bool,
    ) -> Result<(Vec<RowInference>, InferenceStats)> {
        let t0 = self.clock.now();
        // lint:allow(determinism): reported wall_secs is wall-clock telemetry
        let wall0 = std::time::Instant::now();
        let cache = self.cache.clone();
        if let Some(cache) = &cache {
            cache.set_skipping(task.inference.cache_skipping);
        }
        let model_cfg = task.model.clone();

        // Same stage fingerprint as run_inference — over the FULL prompt
        // list even when a stopped run only evaluated a prefix — so
        // `--checkpoint` on a (possibly interrupted) run directory
        // rehydrates its completed ranges byte-identically.
        let temperature = format!("{:.6}", model_cfg.temperature);
        let max_tokens = model_cfg.max_tokens.to_string();
        let mut parts: Vec<&str> = vec![
            "inference",
            &model_cfg.provider,
            &model_cfg.model_name,
            &temperature,
            &max_tokens,
        ];
        parts.extend(prompts.iter().map(|p| p.as_str()));
        let (stage, mut restored, _) =
            self.open_checkpoint_stage("infer", parts, prompts.len(), &RowInference::from_json)?;
        // Rows a stopping-settled run deliberately never evaluated:
        // rehydrate the evaluated prefix only — the saved suffix is not
        // missing work, so no lookups (and no errors) happen for it.
        let skipped = match &stage {
            Some(stage) => stage.skipped()?,
            None => Vec::new(),
        };
        let n_eval = evaluated_prefix_rows(prompts.len(), &skipped)?;
        if n_eval < prompts.len() {
            restored.retain(|(s, _, _)| *s < n_eval);
            for (s, e, rows) in &mut restored {
                if *e > n_eval {
                    *e = n_eval;
                    rows.truncate(n_eval - *s);
                }
            }
        }
        let restored_spans: Vec<(usize, usize)> =
            restored.iter().map(|(s, e, _)| (*s, *e)).collect();
        let df = DataFrame::from_columns(vec![(
            "prompt",
            prompts[..n_eval].iter().map(|p| Value::Str(p.clone())).collect(),
        )])?;
        // Read-only restore: rescore never writes to the run checkpoint.
        let checkpoint =
            (!restored.is_empty()).then_some(TaskCheckpoint { restored, sink: None });

        if cache.is_none() && checkpoint.is_none() {
            bail!(
                "rescore has no response source: open a cache (--cache-dir) \
                 and/or attach a run checkpoint (--checkpoint)"
            );
        }

        let out = run_scheduled_ext(
            &df,
            task.executors,
            task.inference.batch_size,
            &task.scheduler,
            self.progress.as_deref(),
            checkpoint,
            None,
            |_eid| Ok(()),
            |_, df, slice| {
                let mut rows = Vec::with_capacity(slice.len());
                for i in slice.indices() {
                    let prompt = df.row(i).str("prompt");
                    let entry = match &cache {
                        Some(cache) => match cache.get(
                            prompt,
                            &model_cfg.model_name,
                            &model_cfg.provider,
                            model_cfg.temperature,
                            model_cfg.max_tokens,
                        ) {
                            Ok(found) => found,
                            Err(_) if allow_missing => None,
                            Err(e) => return Err(e),
                        },
                        None => None,
                    };
                    match entry {
                        Some(entry) => rows.push(RowInference {
                            response: Some(entry.response_text),
                            from_cache: true,
                            latency_ms: 0.0,
                            cost_usd: 0.0,
                            attempts: 0,
                            error: None,
                        }),
                        None if allow_missing => rows.push(RowInference {
                            response: None,
                            from_cache: false,
                            latency_ms: 0.0,
                            cost_usd: 0.0,
                            attempts: 0,
                            error: Some("rescore: no cached response".into()),
                        }),
                        None => bail!(
                            "rescore: no cached/checkpointed response for example {i} \
                             (run `slleval run` with caching or checkpointing first, \
                             or pass --allow-missing)"
                        ),
                    }
                }
                Ok(rows)
            },
        )?;

        let wall = (self.clock.now() - t0).max(wall0.elapsed().as_secs_f64()).max(1e-9);
        let rows = out.rows;
        let mut stats = InferenceStats {
            examples: rows.len(),
            wall_secs: wall,
            throughput_per_min: rows.len() as f64 / wall * 60.0,
            sched: out.sched,
            timeline: out.timeline,
            concurrency: task.inference.concurrency,
            executors: out.executors,
            ..Default::default()
        };
        // Zero API calls by construction; account lookup traffic only.
        // Checkpoint-restored ranges were not cache lookups this run —
        // they are reported via `sched.restored_rows` instead. Rows
        // without a response count as failed wherever they came from
        // (a checkpointed run's own failures restore as response-less
        // rows), keeping `failed` consistent with `failed_examples`.
        let in_restored = |i: usize| restored_spans.iter().any(|&(s, e)| i >= s && i < e);
        for (i, r) in rows.iter().enumerate() {
            if r.response.is_none() {
                stats.failed += 1;
            } else if r.from_cache && !in_restored(i) {
                stats.cache_hits += 1;
            }
        }
        Ok((rows, stats))
    }
}

/// Builds cache-wrapped, metered judge engines for metric scoring: every
/// judge/RAG call flows through the runner's provider services and
/// response cache, and its traffic lands in the run's [`CallMeter`].
struct RunnerJudgeBroker<'a> {
    runner: &'a EvalRunner,
    meter: Arc<CallMeter>,
}

impl JudgeBroker for RunnerJudgeBroker<'_> {
    fn engine(&self, provider: &str, model: &str) -> Result<Box<dyn InferenceEngine>> {
        let engine = self.runner.make_engine(provider, model)?;
        Ok(Box::new(
            CachedEngine::new(engine, self.runner.cache.clone()).with_meter(self.meter.clone()),
        ))
    }
}

impl Default for EvalRunner {
    fn default() -> Self {
        Self::new()
    }
}

/// Validate that a stage's deliberately-skipped ranges form one clean
/// suffix `[b, total)` and return `b` (`total` when nothing was skipped).
/// The runner only ever skips a single settled-boundary suffix, so any
/// other shape means the checkpoint was hand-edited or corrupt.
fn evaluated_prefix_rows(total: usize, skipped: &[(usize, usize)]) -> Result<usize> {
    if skipped.is_empty() {
        return Ok(total);
    }
    let mut ranges = skipped.to_vec();
    ranges.sort_by_key(|r| r.0);
    let mut end = total;
    for &(start, stop) in ranges.iter().rev() {
        anyhow::ensure!(
            start < stop && stop == end,
            "skipped ranges {skipped:?} do not form a clean suffix of a {total}-row stage"
        );
        end = start;
    }
    Ok(end)
}

fn percentile_from_boots(point: f64, mut boots: Vec<f64>, level: f64) -> stats::ConfidenceInterval {
    boots.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = 1.0 - level;
    stats::ConfidenceInterval {
        point,
        lo: stats::describe::quantile_sorted(&boots, alpha / 2.0),
        hi: stats::describe::quantile_sorted(&boots, 1.0 - alpha / 2.0),
        level,
        method: "percentile_device",
    }
}
