//! Cache middleware for any [`InferenceEngine`].
//!
//! Wraps an engine with the response cache so *every* LLM call in the
//! system — main inference, judge metrics, RAG claim verification — flows
//! through the same content-addressable cache (the property that makes
//! replay mode cover metric iteration end to end).

use crate::cache::ResponseCache;
use crate::providers::{ApiError, InferenceEngine, InferenceRequest, InferenceResponse};
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Aggregated metric-stage call traffic (judge / RAG verification calls):
/// what actually hit the provider vs. what the cache served. This is what
/// lets `replay`/`rescore` report judge cache traffic honestly instead of
/// assuming everything was free.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CallStats {
    /// Calls that reached the provider (billed).
    pub api_calls: u64,
    /// Calls served from the response cache.
    pub cache_hits: u64,
    /// Calls that failed (provider error, or a replay-mode cache miss).
    pub failed: u64,
    /// Provider spend across the billed calls.
    pub cost_usd: f64,
}

impl CallStats {
    pub fn total(&self) -> u64 {
        self.api_calls + self.cache_hits + self.failed
    }
}

/// Shared call meter: engines built for metric scoring report into one of
/// these so the run can account for every judge call it triggered.
#[derive(Debug, Default)]
pub struct CallMeter(Mutex<CallStats>);

impl CallMeter {
    pub fn record_hit(&self) {
        self.0.lock().unwrap().cache_hits += 1;
    }

    pub fn record_call(&self, cost_usd: f64) {
        let mut s = self.0.lock().unwrap();
        s.api_calls += 1;
        s.cost_usd += cost_usd;
    }

    pub fn record_failure(&self) {
        self.0.lock().unwrap().failed += 1;
    }

    pub fn stats(&self) -> CallStats {
        *self.0.lock().unwrap()
    }
}

pub struct CachedEngine<E: InferenceEngine> {
    inner: E,
    cache: Option<Arc<ResponseCache>>,
    pub hits: u64,
    pub misses: u64,
    meter: Option<Arc<CallMeter>>,
}

impl<E: InferenceEngine> CachedEngine<E> {
    pub fn new(inner: E, cache: Option<Arc<ResponseCache>>) -> Self {
        Self { inner, cache, hits: 0, misses: 0, meter: None }
    }

    /// Report every call's outcome (hit / billed / failed) into `meter`.
    pub fn with_meter(mut self, meter: Arc<CallMeter>) -> Self {
        self.meter = Some(meter);
        self
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    fn record(&self, f: impl FnOnce(&CallMeter)) {
        if let Some(m) = &self.meter {
            f(m);
        }
    }

    /// Cache lookup shared by the blocking and pipelined paths. `Some` is
    /// a settled outcome (hit, or a replay-mode miss error); `None` means
    /// the inner engine must be consulted.
    fn lookup(
        &mut self,
        request: &InferenceRequest,
    ) -> Option<Result<InferenceResponse, ApiError>> {
        let (provider, model) = self.inner.model_id();
        let cache = self.cache.as_ref()?;
        match cache.get(&request.prompt, &model, &provider, request.temperature, request.max_tokens)
        {
            Ok(Some(entry)) => {
                self.hits += 1;
                self.record(|m| m.record_hit());
                Some(Ok(InferenceResponse {
                    text: entry.response_text,
                    input_tokens: entry.input_tokens,
                    output_tokens: entry.output_tokens,
                    latency_ms: 0.0, // served locally
                    cost_usd: 0.0,
                }))
            }
            Ok(None) => {
                self.misses += 1;
                None
            }
            // Replay-mode miss: surface as a non-recoverable error.
            Err(e) => {
                self.record(|m| m.record_failure());
                Some(Err(ApiError::InvalidRequest(format!("{e}"))))
            }
        }
    }

    /// Meter and cache-write an inner-engine outcome.
    fn settle(
        &mut self,
        request: &InferenceRequest,
        result: Result<InferenceResponse, ApiError>,
    ) -> Result<InferenceResponse, ApiError> {
        let resp = match result {
            Ok(resp) => resp,
            Err(e) => {
                self.record(|m| m.record_failure());
                return Err(e);
            }
        };
        self.record(|m| m.record_call(resp.cost_usd));
        if let Some(cache) = &self.cache {
            let (provider, model) = self.inner.model_id();
            let _ = cache.put(
                &request.prompt,
                &model,
                &provider,
                request.temperature,
                request.max_tokens,
                &resp,
            );
        }
        Ok(resp)
    }
}

impl<E: InferenceEngine> InferenceEngine for CachedEngine<E> {
    fn initialize(&mut self) -> Result<()> {
        self.inner.initialize()
    }

    fn infer(&mut self, request: &InferenceRequest) -> Result<InferenceResponse, ApiError> {
        match self.lookup(request) {
            Some(served) => served,
            // Blocking path: the inner `infer` sleeps its own latency.
            None => {
                let result = self.inner.infer(request);
                self.settle(request, result)
            }
        }
    }

    /// Cache middleware for the pipelined path: hits are served locally
    /// with zero remaining wait; misses defer to the inner engine and
    /// carry its latency wait through, so in-flight judge slots overlap
    /// provider latency exactly like main inference.
    fn infer_deferred(
        &mut self,
        request: &InferenceRequest,
    ) -> (Result<InferenceResponse, ApiError>, f64) {
        match self.lookup(request) {
            Some(served) => (served, 0.0),
            None => {
                let (result, wait_secs) = self.inner.infer_deferred(request);
                (self.settle(request, result), wait_secs)
            }
        }
    }

    fn shutdown(&mut self) {
        self.inner.shutdown()
    }

    fn model_id(&self) -> (String, String) {
        self.inner.model_id()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CachePolicy;
    use crate::providers::simulated::{SimEngine, SimService, SimServiceConfig};
    use crate::ratelimit::VirtualClock;

    fn sim_engine() -> SimEngine {
        let clock = VirtualClock::new();
        let svc = SimService::new(
            "openai",
            SimServiceConfig {
                server_error_rate: 0.0,
                unparseable_rate: 0.0,
                sleep_latency: false,
                ..Default::default()
            },
            clock.clone(),
        );
        let mut e = SimEngine::new(svc, "openai", "gpt-4o", clock).unwrap();
        e.initialize().unwrap();
        e
    }

    fn tmp_cache(name: &str, policy: CachePolicy) -> Arc<ResponseCache> {
        let dir = std::env::temp_dir()
            .join("slleval-cachedengine")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Arc::new(ResponseCache::open(&dir, policy).unwrap())
    }

    #[test]
    fn second_call_hits() {
        let cache = tmp_cache("hits", CachePolicy::Enabled);
        let mut e = CachedEngine::new(sim_engine(), Some(cache));
        let req = InferenceRequest::new("Question: what is the capital of peru?");
        let r1 = e.infer(&req).unwrap();
        let r2 = e.infer(&req).unwrap();
        assert_eq!(r1.text, r2.text);
        assert_eq!(e.hits, 1);
        assert_eq!(e.misses, 1);
        assert_eq!(r2.cost_usd, 0.0);
    }

    #[test]
    fn replay_miss_is_fatal() {
        let cache = tmp_cache("replaymiss", CachePolicy::Replay);
        let mut e = CachedEngine::new(sim_engine(), Some(cache));
        let req = InferenceRequest::new("never seen before");
        let err = e.infer(&req).unwrap_err();
        assert!(!err.recoverable());
    }

    #[test]
    fn no_cache_passthrough() {
        let mut e = CachedEngine::new(sim_engine(), None);
        let req = InferenceRequest::new("x");
        assert!(e.infer(&req).is_ok());
        assert_eq!(e.hits + e.misses, 0);
    }

    #[test]
    fn meter_separates_billed_from_served() {
        let cache = tmp_cache("meter", CachePolicy::Enabled);
        let meter = Arc::new(CallMeter::default());
        let mut e = CachedEngine::new(sim_engine(), Some(cache)).with_meter(meter.clone());
        let req = InferenceRequest::new("Question: what is the capital of chile?");
        e.infer(&req).unwrap();
        e.infer(&req).unwrap();
        let s = meter.stats();
        assert_eq!(s.api_calls, 1);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.failed, 0);
        assert!(s.cost_usd > 0.0);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn meter_counts_replay_misses_as_failures() {
        let cache = tmp_cache("meter-replay", CachePolicy::Replay);
        let meter = Arc::new(CallMeter::default());
        let mut e = CachedEngine::new(sim_engine(), Some(cache)).with_meter(meter.clone());
        assert!(e.infer(&InferenceRequest::new("cold")).is_err());
        let s = meter.stats();
        assert_eq!((s.api_calls, s.cache_hits, s.failed), (0, 0, 1));
        assert_eq!(s.cost_usd, 0.0);
    }
}
