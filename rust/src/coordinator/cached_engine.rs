//! Cache middleware for any [`InferenceEngine`].
//!
//! Wraps an engine with the response cache so *every* LLM call in the
//! system — main inference, judge metrics, RAG claim verification — flows
//! through the same content-addressable cache (the property that makes
//! replay mode cover metric iteration end to end).

use crate::cache::ResponseCache;
use crate::providers::{ApiError, InferenceEngine, InferenceRequest, InferenceResponse};
use anyhow::Result;
use std::sync::Arc;

pub struct CachedEngine<E: InferenceEngine> {
    inner: E,
    cache: Option<Arc<ResponseCache>>,
    pub hits: u64,
    pub misses: u64,
}

impl<E: InferenceEngine> CachedEngine<E> {
    pub fn new(inner: E, cache: Option<Arc<ResponseCache>>) -> Self {
        Self { inner, cache, hits: 0, misses: 0 }
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: InferenceEngine> InferenceEngine for CachedEngine<E> {
    fn initialize(&mut self) -> Result<()> {
        self.inner.initialize()
    }

    fn infer(&mut self, request: &InferenceRequest) -> Result<InferenceResponse, ApiError> {
        let (provider, model) = self.inner.model_id();
        if let Some(cache) = &self.cache {
            match cache.get(&request.prompt, &model, &provider, request.temperature, request.max_tokens)
            {
                Ok(Some(entry)) => {
                    self.hits += 1;
                    return Ok(InferenceResponse {
                        text: entry.response_text,
                        input_tokens: entry.input_tokens,
                        output_tokens: entry.output_tokens,
                        latency_ms: 0.0, // served locally
                        cost_usd: 0.0,
                    });
                }
                Ok(None) => {
                    self.misses += 1;
                }
                // Replay-mode miss: surface as a non-recoverable error.
                Err(e) => return Err(ApiError::InvalidRequest(format!("{e}"))),
            }
        }
        let resp = self.inner.infer(request)?;
        if let Some(cache) = &self.cache {
            let _ = cache.put(
                &request.prompt,
                &model,
                &provider,
                request.temperature,
                request.max_tokens,
                &resp,
            );
        }
        Ok(resp)
    }

    fn shutdown(&mut self) {
        self.inner.shutdown()
    }

    fn model_id(&self) -> (String, String) {
        self.inner.model_id()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CachePolicy;
    use crate::providers::simulated::{SimEngine, SimService, SimServiceConfig};
    use crate::ratelimit::VirtualClock;

    fn sim_engine() -> SimEngine {
        let clock = VirtualClock::new();
        let svc = SimService::new(
            "openai",
            SimServiceConfig {
                server_error_rate: 0.0,
                unparseable_rate: 0.0,
                sleep_latency: false,
                ..Default::default()
            },
            clock.clone(),
        );
        let mut e = SimEngine::new(svc, "openai", "gpt-4o", clock).unwrap();
        e.initialize().unwrap();
        e
    }

    fn tmp_cache(name: &str, policy: CachePolicy) -> Arc<ResponseCache> {
        let dir = std::env::temp_dir()
            .join("slleval-cachedengine")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Arc::new(ResponseCache::open(&dir, policy).unwrap())
    }

    #[test]
    fn second_call_hits() {
        let cache = tmp_cache("hits", CachePolicy::Enabled);
        let mut e = CachedEngine::new(sim_engine(), Some(cache));
        let req = InferenceRequest::new("Question: what is the capital of peru?");
        let r1 = e.infer(&req).unwrap();
        let r2 = e.infer(&req).unwrap();
        assert_eq!(r1.text, r2.text);
        assert_eq!(e.hits, 1);
        assert_eq!(e.misses, 1);
        assert_eq!(r2.cost_usd, 0.0);
    }

    #[test]
    fn replay_miss_is_fatal() {
        let cache = tmp_cache("replaymiss", CachePolicy::Replay);
        let mut e = CachedEngine::new(sim_engine(), Some(cache));
        let req = InferenceRequest::new("never seen before");
        let err = e.infer(&req).unwrap_err();
        assert!(!err.recoverable());
    }

    #[test]
    fn no_cache_passthrough() {
        let mut e = CachedEngine::new(sim_engine(), None);
        let req = InferenceRequest::new("x");
        assert!(e.infer(&req).is_ok());
        assert_eq!(e.hits + e.misses, 0);
    }
}
