//! Columnar DataFrame: the unit of work the engine partitions.
//!
//! Deliberately small — named columns of [`Value`]s with row views,
//! selection, and column append. Mirrors the subset of the Spark DataFrame
//! API the paper's pipeline uses (`withColumn`, `select`, partitioned
//! iteration).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt;

/// A cell value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    /// List of strings (e.g. retrieved context chunks).
    StrList(Vec<String>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            Value::Bool(b) => Some(*b as i64 as f64),
            _ => None,
        }
    }

    pub fn as_str_list(&self) -> Option<&[String]> {
        match self {
            Value::StrList(v) => Some(v),
            _ => None,
        }
    }

    /// Render as display text (used by templates and metrics).
    pub fn text(&self) -> String {
        match self {
            Value::Null => String::new(),
            Value::Bool(b) => b.to_string(),
            Value::Int(i) => i.to_string(),
            Value::Float(f) => f.to_string(),
            Value::Str(s) => s.clone(),
            Value::StrList(v) => v.join("\n"),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Value::Null => Json::Null,
            Value::Bool(b) => Json::Bool(*b),
            Value::Int(i) => Json::Num(*i as f64),
            Value::Float(f) => Json::Num(*f),
            Value::Str(s) => Json::Str(s.clone()),
            Value::StrList(v) => Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect()),
        }
    }

    pub fn from_json(v: &Json) -> Value {
        match v {
            Json::Null => Value::Null,
            Json::Bool(b) => Value::Bool(*b),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    Value::Int(*n as i64)
                } else {
                    Value::Float(*n)
                }
            }
            Json::Str(s) => Value::Str(s.clone()),
            Json::Arr(a) => Value::StrList(
                a.iter()
                    .map(|x| match x {
                        Json::Str(s) => s.clone(),
                        other => other.to_string(),
                    })
                    .collect(),
            ),
            Json::Obj(_) => Value::Str(v.to_string()),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.text())
    }
}

/// Borrowed row view.
#[derive(Debug, Clone)]
pub struct Row<'a> {
    pub index: usize,
    df: &'a DataFrame,
}

impl<'a> Row<'a> {
    pub fn get(&self, col: &str) -> Option<&'a Value> {
        self.df.column(col).map(|c| &c[self.index])
    }

    pub fn str(&self, col: &str) -> &'a str {
        self.get(col).and_then(|v| v.as_str()).unwrap_or("")
    }

    /// Row as a JSON object (template rendering scope).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for name in &self.df.order {
            obj.insert(name.clone(), self.df.columns[name][self.index].to_json());
        }
        Json::Obj(obj)
    }
}

/// Columnar table with stable column order.
#[derive(Debug, Clone, Default)]
pub struct DataFrame {
    columns: BTreeMap<String, Vec<Value>>,
    /// Column insertion order (presentation + serialization order).
    order: Vec<String>,
    rows: usize,
}

impl DataFrame {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from (name, values) pairs; all columns must be equal length.
    pub fn from_columns(cols: Vec<(&str, Vec<Value>)>) -> Result<Self> {
        let mut df = DataFrame::new();
        for (name, values) in cols {
            df.add_column(name, values)?;
        }
        Ok(df)
    }

    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn column_names(&self) -> &[String] {
        &self.order
    }

    pub fn column(&self, name: &str) -> Option<&[Value]> {
        self.columns.get(name).map(|c| c.as_slice())
    }

    pub fn has_column(&self, name: &str) -> bool {
        self.columns.contains_key(name)
    }

    pub fn add_column(&mut self, name: &str, values: Vec<Value>) -> Result<()> {
        if !self.order.is_empty() && values.len() != self.rows {
            bail!(
                "column '{name}' has {} rows, expected {}",
                values.len(),
                self.rows
            );
        }
        if self.columns.contains_key(name) {
            bail!("column '{name}' already exists");
        }
        self.rows = values.len();
        self.order.push(name.to_string());
        self.columns.insert(name.to_string(), values);
        Ok(())
    }

    /// Replace or add a column (Spark `withColumn`).
    pub fn with_column(mut self, name: &str, values: Vec<Value>) -> Result<Self> {
        if values.len() != self.rows && !self.order.is_empty() {
            bail!("with_column '{name}': length mismatch");
        }
        if !self.columns.contains_key(name) {
            self.order.push(name.to_string());
            self.rows = values.len();
        }
        self.columns.insert(name.to_string(), values);
        Ok(self)
    }

    pub fn row(&self, index: usize) -> Row<'_> {
        assert!(index < self.rows, "row {index} out of bounds ({})", self.rows);
        Row { index, df: self }
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = Row<'_>> {
        (0..self.rows).map(move |i| self.row(i))
    }

    /// Select a subset of rows by index (clones values).
    pub fn take(&self, indices: &[usize]) -> Result<DataFrame> {
        let mut df = DataFrame::new();
        for name in &self.order {
            let src = &self.columns[name];
            let vals = indices
                .iter()
                .map(|&i| {
                    src.get(i)
                        .cloned()
                        .ok_or_else(|| anyhow!("take index {i} out of bounds"))
                })
                .collect::<Result<Vec<_>>>()?;
            df.add_column(name, vals)?;
        }
        df.rows = indices.len();
        Ok(df)
    }

    /// Split into `n` contiguous partitions of near-equal size (Spark
    /// range partitioning). Returns the row-index ranges.
    pub fn partition_ranges(&self, n: usize) -> Vec<std::ops::Range<usize>> {
        let n = n.max(1);
        let total = self.rows;
        let base = total / n;
        let extra = total % n;
        let mut ranges = Vec::with_capacity(n);
        let mut start = 0;
        for i in 0..n {
            let size = base + usize::from(i < extra);
            ranges.push(start..start + size);
            start += size;
        }
        ranges
    }

    /// Vertically concatenate frames with identical schemas.
    pub fn concat(frames: &[DataFrame]) -> Result<DataFrame> {
        let Some(first) = frames.first() else {
            return Ok(DataFrame::new());
        };
        let mut df = DataFrame::new();
        for name in &first.order {
            let mut vals = Vec::new();
            for f in frames {
                let col = f
                    .column(name)
                    .ok_or_else(|| anyhow!("concat: column '{name}' missing"))?;
                vals.extend_from_slice(col);
            }
            df.add_column(name, vals)?;
        }
        Ok(df)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DataFrame {
        DataFrame::from_columns(vec![
            (
                "prompt",
                vec![Value::Str("a".into()), Value::Str("b".into()), Value::Str("c".into())],
            ),
            ("id", vec![Value::Int(0), Value::Int(1), Value::Int(2)]),
        ])
        .unwrap()
    }

    #[test]
    fn build_and_access() {
        let df = sample();
        assert_eq!(df.len(), 3);
        assert_eq!(df.row(1).str("prompt"), "b");
        assert_eq!(df.row(2).get("id"), Some(&Value::Int(2)));
        assert_eq!(df.column_names(), &["prompt", "id"]);
    }

    #[test]
    fn length_mismatch_rejected() {
        let mut df = sample();
        assert!(df.add_column("bad", vec![Value::Null]).is_err());
    }

    #[test]
    fn duplicate_column_rejected() {
        let mut df = sample();
        assert!(df.add_column("prompt", vec![Value::Null; 3]).is_err());
    }

    #[test]
    fn with_column_replaces() {
        let df = sample()
            .with_column("prompt", vec![Value::Str("x".into()); 3])
            .unwrap();
        assert_eq!(df.row(0).str("prompt"), "x");
        assert_eq!(df.column_names().len(), 2);
    }

    #[test]
    fn take_subset() {
        let df = sample().take(&[2, 0]).unwrap();
        assert_eq!(df.len(), 2);
        assert_eq!(df.row(0).str("prompt"), "c");
        assert_eq!(df.row(1).str("prompt"), "a");
        assert!(sample().take(&[99]).is_err());
    }

    #[test]
    fn partition_ranges_cover_disjoint() {
        let df = DataFrame::from_columns(vec![(
            "x",
            (0..10).map(Value::Int).collect::<Vec<_>>(),
        )])
        .unwrap();
        let ranges = df.partition_ranges(3);
        assert_eq!(ranges.len(), 3);
        assert_eq!(ranges[0], 0..4);
        assert_eq!(ranges[1], 4..7);
        assert_eq!(ranges[2], 7..10);
        let total: usize = ranges.iter().map(|r| r.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn partition_more_than_rows() {
        let df = DataFrame::from_columns(vec![("x", vec![Value::Int(1)])]).unwrap();
        let ranges = df.partition_ranges(4);
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), 1);
        assert_eq!(ranges.len(), 4);
    }

    #[test]
    fn concat_roundtrip() {
        let df = sample();
        let parts: Vec<DataFrame> = df
            .partition_ranges(2)
            .into_iter()
            .map(|r| df.take(&r.collect::<Vec<_>>()).unwrap())
            .collect();
        let whole = DataFrame::concat(&parts).unwrap();
        assert_eq!(whole.len(), 3);
        assert_eq!(whole.row(2).str("prompt"), "c");
    }

    #[test]
    fn row_to_json() {
        let df = sample();
        let j = df.row(0).to_json();
        assert_eq!(j.get("prompt").unwrap().as_str().unwrap(), "a");
        assert_eq!(j.get("id").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn value_conversions() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::from_json(&Json::Num(2.0)), Value::Int(2));
        assert_eq!(Value::from_json(&Json::Num(2.5)), Value::Float(2.5));
        let list = Value::StrList(vec!["a".into(), "b".into()]);
        assert_eq!(list.text(), "a\nb");
        assert_eq!(Value::from_json(&list.to_json()), list);
    }
}
