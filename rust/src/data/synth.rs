//! Synthetic evaluation datasets (paper §5.1).
//!
//! The paper samples from three domains: factual QA (Natural-Questions
//! style), summarization (CNN/DailyMail style), and instruction following
//! (Alpaca style). We generate structurally equivalent synthetic examples
//! from seeded templates: every example carries a `prompt`, a `reference`
//! answer, a `domain` tag, and (for RAG workloads) retrieved `context`
//! chunks with a known gold chunk — so every metric family has the columns
//! it needs and ground truth is known by construction.

use super::dataframe::{DataFrame, Value};
use crate::util::rng::Rng;
use anyhow::Result;

/// Domain mix fractions (qa, summarization, instruction).
#[derive(Debug, Clone, Copy)]
pub struct DomainMix {
    pub qa: f64,
    pub summarization: f64,
    pub instruction: f64,
}

impl Default for DomainMix {
    fn default() -> Self {
        Self { qa: 0.4, summarization: 0.3, instruction: 0.3 }
    }
}

/// (country, capital, description) knowledge base shared with the
/// simulated provider's solver (the "model weights" of the simulation).
pub const ENTITIES: &[(&str, &str, &str)] = &[
    ("france", "paris", "a european country on the atlantic"),
    ("japan", "tokyo", "an island nation in east asia"),
    ("brazil", "brasilia", "the largest country in south america"),
    ("canada", "ottawa", "a north american country with vast forests"),
    ("egypt", "cairo", "a country spanning northeast africa"),
    ("kenya", "nairobi", "an east african country on the equator"),
    ("norway", "oslo", "a nordic country of fjords"),
    ("peru", "lima", "an andean country on the pacific"),
    ("india", "new delhi", "a populous country in south asia"),
    ("australia", "canberra", "a continent country in oceania"),
    ("germany", "berlin", "a central european industrial nation"),
    ("italy", "rome", "a mediterranean peninsula country"),
    ("spain", "madrid", "an iberian country with diverse regions"),
    ("portugal", "lisbon", "a country on the iberian atlantic coast"),
    ("greece", "athens", "a country of islands in the aegean"),
    ("turkey", "ankara", "a country bridging europe and asia"),
    ("poland", "warsaw", "a central european country on the baltic"),
    ("sweden", "stockholm", "a scandinavian country of lakes"),
    ("finland", "helsinki", "a nordic country of forests"),
    ("austria", "vienna", "an alpine country in central europe"),
    ("switzerland", "bern", "a mountainous confederation in europe"),
    ("netherlands", "amsterdam", "a low-lying country of canals"),
    ("belgium", "brussels", "a small country at europe's crossroads"),
    ("ireland", "dublin", "an island nation in the north atlantic"),
    ("mexico", "mexico city", "a north american country of high plateaus"),
    ("argentina", "buenos aires", "a south american country of pampas"),
    ("chile", "santiago", "a long thin country along the andes"),
    ("colombia", "bogota", "a south american country on two oceans"),
    ("morocco", "rabat", "a north african kingdom by the atlantic"),
    ("nigeria", "abuja", "the most populous african country"),
    ("ethiopia", "addis ababa", "a highland country in the horn of africa"),
    ("tanzania", "dodoma", "an east african country with great plains"),
    ("ghana", "accra", "a west african country on the gulf of guinea"),
    ("vietnam", "hanoi", "a southeast asian country along the coast"),
    ("thailand", "bangkok", "a southeast asian kingdom of rivers"),
    ("indonesia", "jakarta", "an archipelago of thousands of islands"),
    ("philippines", "manila", "an island nation in the western pacific"),
    ("south korea", "seoul", "an east asian peninsula nation"),
    ("mongolia", "ulaanbaatar", "a landlocked country of steppes"),
    ("kazakhstan", "astana", "a vast central asian country"),
    ("new zealand", "wellington", "an island country in the south pacific"),
    ("iceland", "reykjavik", "a volcanic island in the north atlantic"),
    ("cuba", "havana", "a caribbean island nation"),
];

const TOPICS: &[&str] = &[
    "the water cycle", "photosynthesis", "plate tectonics", "the printing press",
    "the industrial revolution", "neural networks", "the immune system",
    "supply and demand", "the french revolution", "volcanic eruptions",
    "ocean currents", "renewable energy", "ancient trade routes",
    "the human genome", "weather fronts", "antibiotic resistance",
    "glacier formation", "the silk road", "quantum computing", "coral reefs",
    "the space race", "monetary policy", "urban planning", "gene editing",
    "the nitrogen cycle", "sound waves", "medieval guilds", "solar flares",
    "machine translation", "soil erosion", "the telegraph", "deep sea vents",
    "crop rotation", "magnetism", "the roman aqueducts", "bird migration",
    "semiconductor fabrication", "the gold standard", "river deltas",
    "vaccination campaigns", "wind turbines", "the hanseatic league",
];

/// (task stem, ideal answer) pairs for instruction-following examples.
pub const TASKS: &[(&str, &str)] = &[
    ("list three uses for", "practical uses include storage, decoration, and repair"),
    ("write a short definition of", "a concise working definition covering the core concept"),
    ("give a step by step plan to learn", "start with basics, practice daily, then build projects"),
    ("compare and contrast cats and", "both are common companions but differ in temperament"),
    ("suggest a healthy breakfast featuring", "combine whole grains with fruit and protein"),
    ("draft a polite email about", "a short courteous note stating the request clearly"),
    ("explain to a child how", "a simple friendly explanation with an everyday analogy"),
    ("write a haiku about", "three short lines evoking the subject with a seasonal image"),
    ("brainstorm five project ideas around", "five varied ideas ranging from simple to ambitious"),
    ("outline a short presentation on", "an outline with introduction, three points, and a close"),
    ("give safety tips for", "a brief list of precautions and common mistakes to avoid"),
    ("summarize the pros and cons of", "balanced bullet points covering benefits and drawbacks"),
    ("create a quiz question about", "one clear question with a correct answer and distractors"),
    ("recommend resources for learning", "a mix of introductory and advanced materials"),
];

fn sentence_pool(topic: &str) -> Vec<String> {
    vec![
        format!("{topic} involves several interacting stages"),
        format!("researchers have studied {topic} for decades"),
        format!("the key mechanism behind {topic} is energy transfer"),
        format!("many textbooks introduce {topic} with simple diagrams"),
        format!("recent work connects {topic} to climate variability"),
        format!("a common misconception about {topic} is that it is static"),
    ]
}

/// Generate one synthetic example per row for the requested domain mix.
///
/// Columns: `id`, `domain`, `prompt`, `reference`, `question`, `context`
/// (list of chunks, first relevant chunk position in `gold_position`).
pub fn generate(n: usize, seed: u64, mix: DomainMix) -> Result<DataFrame> {
    let mut rng = Rng::new(seed);
    let mut ids = Vec::with_capacity(n);
    let mut domains = Vec::with_capacity(n);
    let mut prompts = Vec::with_capacity(n);
    let mut refs = Vec::with_capacity(n);
    let mut questions = Vec::with_capacity(n);
    let mut contexts = Vec::with_capacity(n);
    let mut gold_pos = Vec::with_capacity(n);

    let total = (mix.qa + mix.summarization + mix.instruction).max(1e-9);
    for i in 0..n {
        let u = rng.f64() * total;
        let (domain, prompt, reference, question, ctx, gold) = if u < mix.qa {
            qa_example(&mut rng)
        } else if u < mix.qa + mix.summarization {
            summarization_example(&mut rng)
        } else {
            instruction_example(&mut rng)
        };
        ids.push(Value::Int(i as i64));
        domains.push(Value::Str(domain.into()));
        prompts.push(Value::Str(prompt));
        refs.push(Value::Str(reference));
        questions.push(Value::Str(question));
        contexts.push(Value::StrList(ctx));
        gold_pos.push(Value::Int(gold));
    }

    DataFrame::from_columns(vec![
        ("id", ids),
        ("domain", domains),
        ("prompt", prompts),
        ("reference", refs),
        ("question", questions),
        ("context", contexts),
        ("gold_position", gold_pos),
    ])
}

/// Convenience: default mix.
pub fn generate_default(n: usize, seed: u64) -> DataFrame {
    generate(n, seed, DomainMix::default()).expect("static schema cannot fail")
}

/// Question phrasings — all contain "capital of <country>" so the
/// simulated model's solver can parse them (like a real model recognizing
/// paraphrases of a known fact).
const QA_TEMPLATES: &[&str] = &[
    "what is the capital of {c}?",
    "which city is the capital of {c}?",
    "name the capital of {c}.",
    "tell me the capital of {c} please.",
    "i need to know the capital of {c}.",
];

const QA_PREFIXES: &[&str] = &[
    "Answer the question concisely.",
    "Provide a short factual answer.",
    "Reply with just the answer, no explanation.",
    "Answer briefly.",
    "Give the single best answer.",
    "Respond with only the requested fact.",
];

fn qa_example(rng: &mut Rng) -> (&'static str, String, String, String, Vec<String>, i64) {
    let (country, capital, desc) = *rng.choose(ENTITIES);
    let question = rng.choose(QA_TEMPLATES).replace("{c}", country);
    let prefix = *rng.choose(QA_PREFIXES);
    let reference = capital.to_string();
    // Retrieved context: one gold chunk + distractors, shuffled.
    let gold_chunk = format!("{country} is {desc}; its capital city is {capital}");
    let mut chunks: Vec<String> = Vec::new();
    chunks.push(gold_chunk.clone());
    while chunks.len() < 4 {
        let (c2, cap2, d2) = *rng.choose(ENTITIES);
        if c2 != country {
            chunks.push(format!("{c2} is {d2}; its capital city is {cap2}"));
        }
    }
    rng.shuffle(&mut chunks[..]);
    let gold = chunks.iter().position(|c| c == &gold_chunk).unwrap() as i64;
    (
        "qa",
        format!("{prefix}\nQuestion: {question}"),
        reference,
        question,
        chunks,
        gold,
    )
}

fn summarization_example(rng: &mut Rng) -> (&'static str, String, String, String, Vec<String>, i64) {
    let topic = *rng.choose(TOPICS);
    let pool = sentence_pool(topic);
    let k = 3 + rng.below(3);
    let idx = rng.sample_indices(pool.len(), k);
    let article: Vec<String> = idx.iter().map(|&i| pool[i].clone()).collect();
    let reference = format!("{}", article[0]); // lead sentence as gold summary
    let question = format!("summarize the article about {topic}");
    let body = article.join(". ");
    (
        "summarization",
        format!("Summarize in one sentence:\n{body}."),
        reference,
        question,
        article,
        0,
    )
}

fn instruction_example(rng: &mut Rng) -> (&'static str, String, String, String, Vec<String>, i64) {
    let (task, answer) = *rng.choose(TASKS);
    let topic = *rng.choose(TOPICS);
    let instruction = format!("{task} {topic}");
    (
        "instruction",
        format!("Instruction: {instruction}\nResponse:"),
        answer.to_string(),
        instruction,
        vec![],
        -1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_and_size() {
        let df = generate_default(100, 1);
        assert_eq!(df.len(), 100);
        for col in ["id", "domain", "prompt", "reference", "question", "context", "gold_position"] {
            assert!(df.has_column(col), "missing {col}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate_default(50, 7);
        let b = generate_default(50, 7);
        for i in 0..50 {
            assert_eq!(a.row(i).str("prompt"), b.row(i).str("prompt"));
        }
    }

    #[test]
    fn different_seed_differs() {
        let a = generate_default(50, 1);
        let b = generate_default(50, 2);
        let same = (0..50).filter(|&i| a.row(i).str("prompt") == b.row(i).str("prompt")).count();
        assert!(same < 50);
    }

    #[test]
    fn domain_mix_respected() {
        let df = generate(3000, 3, DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 }).unwrap();
        for row in df.iter_rows() {
            assert_eq!(row.str("domain"), "qa");
        }
        let df = generate_default(3000, 4);
        let qa = df.iter_rows().filter(|r| r.str("domain") == "qa").count();
        assert!((0.3..0.5).contains(&(qa as f64 / 3000.0)), "qa fraction {qa}");
    }

    #[test]
    fn qa_gold_chunk_contains_answer() {
        let df = generate(200, 5, DomainMix { qa: 1.0, summarization: 0.0, instruction: 0.0 }).unwrap();
        for row in df.iter_rows() {
            let gold = row.get("gold_position").unwrap().as_f64().unwrap() as usize;
            let chunks = row.get("context").unwrap().as_str_list().unwrap();
            let reference = row.str("reference");
            assert!(
                chunks[gold].contains(reference),
                "gold chunk must contain the answer"
            );
        }
    }

    #[test]
    fn instruction_rows_have_no_context() {
        let df = generate(100, 6, DomainMix { qa: 0.0, summarization: 0.0, instruction: 1.0 }).unwrap();
        for row in df.iter_rows() {
            assert!(row.get("context").unwrap().as_str_list().unwrap().is_empty());
            assert_eq!(row.get("gold_position").unwrap().as_f64().unwrap(), -1.0);
        }
    }
}
