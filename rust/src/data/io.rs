//! DataFrame IO: JSONL (one object per line) and CSV.

use super::dataframe::{DataFrame, Value};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Read a JSONL file: every line an object; union of keys becomes the
/// schema, missing cells are Null.
pub fn read_jsonl(path: &Path) -> Result<DataFrame> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let reader = std::io::BufReader::new(file);
    let mut rows: Vec<BTreeMap<String, Json>> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line)
            .with_context(|| format!("{path:?}:{} invalid json", lineno + 1))?;
        rows.push(v.as_obj()?.clone());
    }
    frame_from_objects(rows)
}

fn frame_from_objects(rows: Vec<BTreeMap<String, Json>>) -> Result<DataFrame> {
    let mut keys: Vec<String> = Vec::new();
    for r in &rows {
        for k in r.keys() {
            if !keys.contains(k) {
                keys.push(k.clone());
            }
        }
    }
    let mut df = DataFrame::new();
    for k in &keys {
        let vals = rows
            .iter()
            .map(|r| r.get(k).map(Value::from_json).unwrap_or(Value::Null))
            .collect();
        df.add_column(k, vals)?;
    }
    Ok(df)
}

/// Write a DataFrame as JSONL.
pub fn write_jsonl(df: &DataFrame, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::new(file);
    for row in df.iter_rows() {
        writeln!(w, "{}", row.to_json())?;
    }
    w.flush()?;
    Ok(())
}

/// Minimal RFC-4180 CSV reader (quoted fields, escaped quotes). First row
/// is the header; all cells load as strings.
pub fn read_csv(path: &Path) -> Result<DataFrame> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let mut records = parse_csv(&text)?;
    if records.is_empty() {
        return Ok(DataFrame::new());
    }
    let header = records.remove(0);
    let mut df = DataFrame::new();
    for (ci, name) in header.iter().enumerate() {
        let vals = records
            .iter()
            .map(|r| {
                r.get(ci)
                    .map(|s| Value::Str(s.clone()))
                    .unwrap_or(Value::Null)
            })
            .collect();
        df.add_column(name, vals)?;
    }
    Ok(df)
}

/// Write CSV with quoting where needed.
pub fn write_csv(df: &DataFrame, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    let names = df.column_names().to_vec();
    writeln!(w, "{}", names.iter().map(|n| csv_quote(n)).collect::<Vec<_>>().join(","))?;
    for row in df.iter_rows() {
        let cells: Vec<String> = names.iter().map(|n| csv_quote(&row.get(n).unwrap().text())).collect();
        writeln!(w, "{}", cells.join(","))?;
    }
    w.flush()?;
    Ok(())
}

fn csv_quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

fn parse_csv(text: &str) -> Result<Vec<Vec<String>>> {
    let mut records = Vec::new();
    let mut record = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        bail!("quote inside unquoted field");
                    }
                    in_quotes = true;
                }
                ',' => {
                    record.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut record));
                }
                c => field.push(c),
            }
        }
    }
    if !field.is_empty() || !record.is_empty() {
        record.push(field);
        records.push(record);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("slleval-io-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn jsonl_round_trip() {
        let df = DataFrame::from_columns(vec![
            ("prompt", vec![Value::Str("what is 2+2?".into()), Value::Str("capital of france".into())]),
            ("score", vec![Value::Float(0.5), Value::Int(1)]),
            ("ctx", vec![Value::StrList(vec!["a".into(), "b".into()]), Value::Null]),
        ])
        .unwrap();
        let path = tmp("rt.jsonl");
        write_jsonl(&df, &path).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.row(0).str("prompt"), "what is 2+2?");
        assert_eq!(back.row(0).get("ctx").unwrap().as_str_list().unwrap().len(), 2);
    }

    #[test]
    fn jsonl_ragged_keys() {
        let path = tmp("ragged.jsonl");
        std::fs::write(&path, "{\"a\": 1}\n{\"a\": 2, \"b\": \"x\"}\n").unwrap();
        let df = read_jsonl(&path).unwrap();
        assert_eq!(df.len(), 2);
        assert_eq!(df.row(0).get("b"), Some(&Value::Null));
        assert_eq!(df.row(1).str("b"), "x");
    }

    #[test]
    fn csv_round_trip_with_quoting() {
        let df = DataFrame::from_columns(vec![
            ("text", vec![Value::Str("hello, world".into()), Value::Str("line\nbreak \"q\"".into())]),
            ("plain", vec![Value::Str("a".into()), Value::Str("b".into())]),
        ])
        .unwrap();
        let path = tmp("rt.csv");
        write_csv(&df, &path).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.row(0).str("text"), "hello, world");
        assert_eq!(back.row(1).str("text"), "line\nbreak \"q\"");
    }

    #[test]
    fn invalid_json_line_errors() {
        let path = tmp("bad.jsonl");
        std::fs::write(&path, "{\"a\": 1}\nnot json\n").unwrap();
        assert!(read_jsonl(&path).is_err());
    }
}
