//! Data substrate: the columnar DataFrame the engine partitions, plus IO
//! and the synthetic evaluation datasets of paper §5.1.

pub mod dataframe;
pub mod io;
pub mod synth;

pub use dataframe::{DataFrame, Row, Value};
