//! Serializable task plans — the unit of work an executor backend runs.
//!
//! [`crate::sched::run_scheduled`] accepts opaque closures, which can
//! never cross a process boundary. A [`TaskPlan`] is the closed,
//! JSON-round-trippable description of the work kinds the coordinator
//! actually schedules — inference rows, pure-metric scoring, pairwise
//! judging — plus the execution environment an out-of-process worker
//! needs to rebuild the executor-local state the closures used to
//! capture (provider service config, clock mode, cache location) and the
//! content-addressed checkpoint stage it spills completed tasks into.
//!
//! The plan is shipped once per executor (the [`ExecutorBackend`]
//! handshake); individual tasks then reference row ranges into the
//! plan's payload, so the per-task messages stay small.
//!
//! [`ExecutorBackend`]: crate::sched::backend::ExecutorBackend

use crate::config::{CachePolicy, InferenceConfig, MetricConfig, ModelConfig};
use crate::metrics::Example;
use crate::providers::simulated::SimServiceConfig;
use crate::util::json::Json;
use anyhow::{bail, Result};

/// One complete executor work specification: the work kind payload, the
/// environment to rebuild engines from, the checkpoint spill target, and
/// (tests only) a deterministic crash-injection hook.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskPlan {
    pub work: PlanWork,
    pub env: PlanEnv,
    /// Worker-side checkpoint spill target (content-addressed stage).
    pub stage: Option<StagePlan>,
    /// Deterministic executor-death injection for offline crash tests.
    pub fault: Option<WorkerFault>,
}

/// The closed set of work kinds the coordinator schedules.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanWork {
    /// Stage-2 distributed inference over a prompt column.
    Inference(InferencePlan),
    /// Stage-3 pure-metric scoring over assembled examples.
    MetricScore(MetricPlan),
    /// Pairwise LLM-judge comparison over response pairs (both orders).
    PairwiseJudge(PairwisePlan),
}

/// Inference-stage plan: per-row prompts plus everything the old
/// `run_inference` executor closure captured by reference.
#[derive(Debug, Clone, PartialEq)]
pub struct InferencePlan {
    pub model: ModelConfig,
    pub inference: InferenceConfig,
    /// Total executor count (token buckets split the global budget).
    pub executors: usize,
    /// Statistics seed (per-slot retry-jitter rng streams).
    pub seed: u64,
    pub prompts: Vec<String>,
}

/// Pure-metric scoring plan. Only registry built-ins are eligible: a
/// custom metric object cannot cross a process boundary, so custom
/// metrics always score in-process.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricPlan {
    pub metric: MetricConfig,
    pub examples: Vec<Example>,
}

/// Pairwise judging plan: one entry per example pair; rows with a missing
/// response score `unscored` without a judge call, like the closure path.
#[derive(Debug, Clone, PartialEq)]
pub struct PairwisePlan {
    pub judge: ModelConfig,
    pub rubric: String,
    /// In-flight judge calls multiplexed per executor.
    pub concurrency: usize,
    pub pairs: Vec<PairInput>,
}

/// One pairwise-judging row.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PairInput {
    pub question: String,
    pub reference: String,
    pub response_a: Option<String>,
    pub response_b: Option<String>,
}

/// Execution environment an out-of-process worker rebuilds: the provider
/// endpoint simulation knobs, the clock mode, and the response cache.
/// In-process (thread) executors share the driver's live handles instead;
/// see `coordinator::plan_exec::PlanHost`.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanEnv {
    pub service: SimServiceConfig,
    /// Rebuild a virtual clock (fast/simulation mode) instead of wall
    /// clock. Each worker process owns its own clock; latency is slept
    /// (or skipped) locally, never coordinated across processes.
    pub virtual_clock: bool,
    pub cache_dir: Option<String>,
    pub cache_policy: CachePolicy,
}

impl Default for PlanEnv {
    fn default() -> Self {
        Self {
            service: SimServiceConfig::default(),
            virtual_clock: false,
            cache_dir: None,
            cache_policy: CachePolicy::Disabled,
        }
    }
}

/// Checkpoint spill target: the stage directory (already created and
/// fingerprint-bound by the driver) workers record completed tasks into.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    pub dir: String,
    /// Content-address of the stage inputs (diagnostics; the directory
    /// name embeds it too).
    pub fingerprint: String,
}

/// Deterministic executor-death injection (offline crash testing): the
/// targeted executor dies hard — `std::process::abort` for a process
/// worker, an unannounced thread exit for a thread worker — while
/// executing its `kill_after_tasks`-th task, losing exactly that
/// in-flight task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerFault {
    pub executor_id: usize,
    /// 1-based index of the task the executor dies on.
    pub kill_after_tasks: usize,
}

impl WorkerFault {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("executor_id", Json::num(self.executor_id as f64)),
            ("kill_after_tasks", Json::num(self.kill_after_tasks as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<WorkerFault> {
        Ok(WorkerFault {
            executor_id: v.get("executor_id")?.as_usize()?,
            kill_after_tasks: v.get("kill_after_tasks")?.as_usize()?,
        })
    }
}

fn model_to_json(m: &ModelConfig) -> Json {
    Json::obj(vec![
        ("provider", Json::str(&m.provider)),
        ("model_name", Json::str(&m.model_name)),
        ("temperature", Json::num(m.temperature)),
        ("max_tokens", Json::num(m.max_tokens as f64)),
    ])
}

fn model_from_json(v: &Json) -> Result<ModelConfig> {
    Ok(ModelConfig {
        provider: v.get("provider")?.as_str()?.to_string(),
        model_name: v.get("model_name")?.as_str()?.to_string(),
        temperature: v.f64_or("temperature", 0.0),
        max_tokens: v.usize_or("max_tokens", 1024),
    })
}

fn inference_cfg_to_json(i: &InferenceConfig) -> Json {
    Json::obj(vec![
        ("batch_size", Json::num(i.batch_size as f64)),
        ("concurrency", Json::num(i.concurrency as f64)),
        ("rate_limit_rpm", Json::num(i.rate_limit_rpm)),
        ("rate_limit_tpm", Json::num(i.rate_limit_tpm)),
        ("cache_policy", Json::str(i.cache_policy.as_str())),
        ("cache_skipping", Json::Bool(i.cache_skipping)),
        ("max_retries", Json::num(i.max_retries as f64)),
        ("retry_delay", Json::num(i.retry_delay)),
        ("adaptive_rate_limits", Json::Bool(i.adaptive_rate_limits)),
        ("max_cost_usd", i.max_cost_usd.map(Json::num).unwrap_or(Json::Null)),
    ])
}

fn inference_cfg_from_json(v: &Json) -> Result<InferenceConfig> {
    let d = InferenceConfig::default();
    Ok(InferenceConfig {
        batch_size: v.usize_or("batch_size", d.batch_size),
        concurrency: v.usize_or("concurrency", d.concurrency),
        rate_limit_rpm: v.f64_or("rate_limit_rpm", d.rate_limit_rpm),
        rate_limit_tpm: v.f64_or("rate_limit_tpm", d.rate_limit_tpm),
        cache_policy: CachePolicy::from_str(v.str_or("cache_policy", "enabled"))?,
        cache_skipping: v.bool_or("cache_skipping", d.cache_skipping),
        max_retries: v.usize_or("max_retries", d.max_retries),
        retry_delay: v.f64_or("retry_delay", d.retry_delay),
        adaptive_rate_limits: v.bool_or("adaptive_rate_limits", false),
        max_cost_usd: v.opt("max_cost_usd").and_then(|b| b.as_f64().ok()),
    })
}

fn metric_cfg_to_json(m: &MetricConfig) -> Json {
    Json::obj(vec![
        ("name", Json::str(&m.name)),
        ("type", Json::str(&m.metric_type)),
        ("params", Json::Obj(m.params.clone())),
    ])
}

fn metric_cfg_from_json(v: &Json) -> Result<MetricConfig> {
    Ok(MetricConfig {
        name: v.get("name")?.as_str()?.to_string(),
        metric_type: v.str_or("type", "lexical").to_string(),
        params: v.opt("params").map(|p| p.as_obj().cloned()).transpose()?.unwrap_or_default(),
    })
}

fn opt_str(v: &Json, key: &str) -> Option<String> {
    v.opt(key).and_then(|s| s.as_str().ok()).map(String::from)
}

impl TaskPlan {
    /// Rows this plan covers (the scheduler tiles `[0, total_rows)`).
    pub fn total_rows(&self) -> usize {
        match &self.work {
            PlanWork::Inference(p) => p.prompts.len(),
            PlanWork::MetricScore(p) => p.examples.len(),
            PlanWork::PairwiseJudge(p) => p.pairs.len(),
        }
    }

    /// Provider whose endpoint the executor talks to (`None` for pure
    /// metric scoring, which makes no provider calls).
    pub fn provider(&self) -> Option<&str> {
        match &self.work {
            PlanWork::Inference(p) => Some(&p.model.provider),
            PlanWork::MetricScore(_) => None,
            PlanWork::PairwiseJudge(p) => Some(&p.judge.provider),
        }
    }

    /// Short work-kind tag (wire format, diagnostics).
    pub fn kind(&self) -> &'static str {
        match &self.work {
            PlanWork::Inference(_) => "inference",
            PlanWork::MetricScore(_) => "metric_score",
            PlanWork::PairwiseJudge(_) => "pairwise_judge",
        }
    }

    pub fn to_json(&self) -> Json {
        let work = match &self.work {
            PlanWork::Inference(p) => Json::obj(vec![
                ("model", model_to_json(&p.model)),
                ("inference", inference_cfg_to_json(&p.inference)),
                ("executors", Json::num(p.executors as f64)),
                ("seed", Json::num(p.seed as f64)),
                ("prompts", Json::arr(p.prompts.iter().map(|s| Json::str(s)).collect())),
            ]),
            PlanWork::MetricScore(p) => Json::obj(vec![
                ("metric", metric_cfg_to_json(&p.metric)),
                ("examples", Json::arr(p.examples.iter().map(|e| e.to_json()).collect())),
            ]),
            PlanWork::PairwiseJudge(p) => Json::obj(vec![
                ("judge", model_to_json(&p.judge)),
                ("rubric", Json::str(&p.rubric)),
                ("concurrency", Json::num(p.concurrency as f64)),
                (
                    "pairs",
                    Json::arr(
                        p.pairs
                            .iter()
                            .map(|pair| {
                                Json::obj(vec![
                                    ("question", Json::str(&pair.question)),
                                    ("reference", Json::str(&pair.reference)),
                                    (
                                        "response_a",
                                        pair.response_a
                                            .as_deref()
                                            .map(Json::str)
                                            .unwrap_or(Json::Null),
                                    ),
                                    (
                                        "response_b",
                                        pair.response_b
                                            .as_deref()
                                            .map(Json::str)
                                            .unwrap_or(Json::Null),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        };
        Json::obj(vec![
            ("kind", Json::str(self.kind())),
            ("work", work),
            (
                "env",
                Json::obj(vec![
                    ("service", self.env.service.to_json()),
                    ("virtual_clock", Json::Bool(self.env.virtual_clock)),
                    (
                        "cache_dir",
                        self.env.cache_dir.as_deref().map(Json::str).unwrap_or(Json::Null),
                    ),
                    ("cache_policy", Json::str(self.env.cache_policy.as_str())),
                ]),
            ),
            (
                "stage",
                self.stage
                    .as_ref()
                    .map(|s| {
                        Json::obj(vec![
                            ("dir", Json::str(&s.dir)),
                            ("fingerprint", Json::str(&s.fingerprint)),
                        ])
                    })
                    .unwrap_or(Json::Null),
            ),
            ("fault", self.fault.as_ref().map(|f| f.to_json()).unwrap_or(Json::Null)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TaskPlan> {
        let kind = v.get("kind")?.as_str()?;
        let w = v.get("work")?;
        let work = match kind {
            "inference" => PlanWork::Inference(InferencePlan {
                model: model_from_json(w.get("model")?)?,
                inference: inference_cfg_from_json(w.get("inference")?)?,
                executors: w.usize_or("executors", 1),
                seed: w.f64_or("seed", 42.0) as u64,
                prompts: w
                    .get("prompts")?
                    .as_arr()?
                    .iter()
                    .map(|p| Ok(p.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
            }),
            "metric_score" => PlanWork::MetricScore(MetricPlan {
                metric: metric_cfg_from_json(w.get("metric")?)?,
                examples: w
                    .get("examples")?
                    .as_arr()?
                    .iter()
                    .map(Example::from_json)
                    .collect::<Result<Vec<_>>>()?,
            }),
            "pairwise_judge" => PlanWork::PairwiseJudge(PairwisePlan {
                judge: model_from_json(w.get("judge")?)?,
                rubric: w.get("rubric")?.as_str()?.to_string(),
                concurrency: w.usize_or("concurrency", 1),
                pairs: w
                    .get("pairs")?
                    .as_arr()?
                    .iter()
                    .map(|p| {
                        Ok(PairInput {
                            question: p.str_or("question", "").to_string(),
                            reference: p.str_or("reference", "").to_string(),
                            response_a: opt_str(p, "response_a"),
                            response_b: opt_str(p, "response_b"),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            }),
            other => bail!("unknown task plan kind '{other}'"),
        };
        let env = match v.opt("env") {
            Some(e) => PlanEnv {
                service: SimServiceConfig::from_json(e.get("service")?)?,
                virtual_clock: e.bool_or("virtual_clock", false),
                cache_dir: opt_str(e, "cache_dir"),
                cache_policy: CachePolicy::from_str(e.str_or("cache_policy", "disabled"))?,
            },
            None => PlanEnv::default(),
        };
        let stage = match v.opt("stage") {
            Some(Json::Null) | None => None,
            Some(s) => Some(StagePlan {
                dir: s.get("dir")?.as_str()?.to_string(),
                fingerprint: s.str_or("fingerprint", "").to_string(),
            }),
        };
        let fault = match v.opt("fault") {
            Some(Json::Null) | None => None,
            Some(f) => Some(WorkerFault::from_json(f)?),
        };
        Ok(TaskPlan { work, env, stage, fault })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn env() -> PlanEnv {
        PlanEnv {
            service: SimServiceConfig { server_error_rate: 0.0, seed: 9, ..Default::default() },
            virtual_clock: true,
            cache_dir: Some("/tmp/cache".into()),
            cache_policy: CachePolicy::Enabled,
        }
    }

    #[test]
    fn inference_plan_round_trips() {
        let plan = TaskPlan {
            work: PlanWork::Inference(InferencePlan {
                model: ModelConfig { temperature: 0.5, ..Default::default() },
                inference: InferenceConfig {
                    concurrency: 4,
                    max_cost_usd: Some(2.5),
                    ..Default::default()
                },
                executors: 3,
                seed: 7,
                prompts: vec!["a".into(), "b".into(), "c".into()],
            }),
            env: env(),
            stage: Some(StagePlan { dir: "/tmp/run/infer-abc".into(), fingerprint: "abc".into() }),
            fault: Some(WorkerFault { executor_id: 1, kill_after_tasks: 2 }),
        };
        let restored = TaskPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, restored);
        assert_eq!(restored.total_rows(), 3);
        assert_eq!(restored.provider(), Some("openai"));
        // The wire format survives a text round trip too (IPC framing
        // ships the serialized text, not the in-memory value).
        let text = plan.to_json().to_string();
        assert_eq!(TaskPlan::from_json(&Json::parse(&text).unwrap()).unwrap(), plan);
    }

    #[test]
    fn metric_plan_round_trips() {
        let plan = TaskPlan {
            work: PlanWork::MetricScore(MetricPlan {
                metric: MetricConfig::new("exact_match", "lexical"),
                examples: vec![
                    Example {
                        prompt: "p".into(),
                        response: "r".into(),
                        reference: "r".into(),
                        question: "q".into(),
                        context: vec!["c1".into(), "c2".into()],
                        gold_position: 1,
                    },
                    Example::default(),
                ],
            }),
            env: PlanEnv::default(),
            stage: None,
            fault: None,
        };
        let restored = TaskPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, restored);
        assert_eq!(restored.provider(), None);
        assert_eq!(restored.kind(), "metric_score");
    }

    #[test]
    fn pairwise_plan_round_trips() {
        let plan = TaskPlan {
            work: PlanWork::PairwiseJudge(PairwisePlan {
                judge: ModelConfig::default(),
                rubric: "accuracy".into(),
                concurrency: 2,
                pairs: vec![
                    PairInput {
                        question: "q".into(),
                        reference: "ref".into(),
                        response_a: Some("a".into()),
                        response_b: Some("b".into()),
                    },
                    PairInput { response_a: None, response_b: Some("b".into()), ..Default::default() },
                ],
            }),
            env: env(),
            stage: None,
            fault: None,
        };
        assert_eq!(TaskPlan::from_json(&plan.to_json()).unwrap(), plan);
    }
}
