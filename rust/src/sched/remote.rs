//! [`RemoteBackend`]: executors on remote hosts over TCP.
//!
//! The driver side of the remote transport. Each executor slot is one
//! TCP connection to a `slleval serve-worker` daemon from the configured
//! host list (`executor.hosts` / `--hosts`), assigned round-robin:
//! executor `e` lives on `hosts[e % hosts.len()]`. The connection speaks
//! the exact worker protocol the [`ProcessBackend`] speaks over pipes
//! (see [`super::wire`] and the frame table in [`super::backend`]), so a
//! remote run is bit-identical to a thread or process run of the same
//! plan — the serve daemon rebuilds the same deterministic `PlanHost`
//! from the shipped plan.
//!
//! What TCP adds over pipes is *partial* failure, and the backend turns
//! every flavor of it into the driver's existing death machinery:
//!
//! - **hung socket** → the reader's `read_timeout` (the heartbeat
//!   window; serve workers emit `{"type":"heartbeat"}` every second)
//!   expires and the executor becomes [`ExecutorEvent::Died`] instead of
//!   wedging the poll loop;
//! - **connection loss / torn frame** → `Died` with the transport error;
//! - **host failure domains** → [`ExecutorBackend::host_of`] reports the
//!   host index, so the driver settles *all* of a dead host's executors
//!   at once and counts a `host_death`.
//!
//! Checkpoint spills cannot be written worker-side (no shared
//! filesystem), so serve workers upload each completed task's rows as a
//! `{"type":"spill",...}` frame *before* the result frame; the reader
//! thread records them into the driver-side stage, preserving
//! `--resume`'s zero-re-inference guarantee across host kills.
//!
//! [`ProcessBackend`]: super::backend::ProcessBackend

use super::backend::{worker_frame_to_event, ExecutorBackend, ExecutorEvent, TaskSpec};
use super::plan::TaskPlan;
use super::wire::{is_timeout, read_frame, write_frame, write_frame_bytes};
use crate::checkpoint::StageCheckpoint;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Default heartbeat window: a connection that produces no frame (not
/// even a heartbeat) for this long is settled as dead.
pub const DEFAULT_HEARTBEAT_TIMEOUT: Duration = Duration::from_secs(30);

/// Environment override for the heartbeat window, in (possibly
/// fractional) seconds.
pub const REMOTE_HEARTBEAT_ENV: &str = "SLLEVAL_REMOTE_HEARTBEAT_SECS";

/// The heartbeat window: [`REMOTE_HEARTBEAT_ENV`] when set and positive,
/// else [`DEFAULT_HEARTBEAT_TIMEOUT`].
pub fn heartbeat_timeout_from_env() -> Duration {
    parse_heartbeat_timeout(std::env::var(REMOTE_HEARTBEAT_ENV).ok().as_deref())
}

fn parse_heartbeat_timeout(value: Option<&str>) -> Duration {
    value
        .and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|&secs| secs > 0.0)
        .map(Duration::from_secs_f64)
        .unwrap_or(DEFAULT_HEARTBEAT_TIMEOUT)
}

/// TCP backend: one socket per executor to a `slleval serve-worker`
/// host, round-robin over the host list.
pub struct RemoteBackend {
    /// The plan, serialized once; spliced verbatim into hello frames.
    plan_text: String,
    batch_size: usize,
    hosts: Vec<String>,
    heartbeat_timeout: Duration,
    /// Driver-side stage for uploaded spill frames (`--resume` support);
    /// shared with every reader thread.
    stage: Option<Arc<StageCheckpoint>>,
    streams: Vec<Option<TcpStream>>,
    readers: Vec<Option<std::thread::JoinHandle<()>>>,
    events_tx: mpsc::Sender<ExecutorEvent>,
    events_rx: mpsc::Receiver<ExecutorEvent>,
    /// Set before tearing sockets down so clean-shutdown EOFs are not
    /// reported as deaths.
    closing: Arc<AtomicBool>,
}

impl RemoteBackend {
    pub fn new(
        plan: &TaskPlan,
        executors: usize,
        batch_size: usize,
        hosts: Vec<String>,
        heartbeat_timeout: Duration,
        stage: Option<Arc<StageCheckpoint>>,
    ) -> Result<Self> {
        anyhow::ensure!(
            !hosts.is_empty(),
            "the remote backend requires at least one serve-worker host \
             (executor.hosts / --hosts)"
        );
        let (events_tx, events_rx) = mpsc::channel();
        Ok(Self {
            plan_text: plan.to_json().to_string(),
            batch_size,
            hosts,
            heartbeat_timeout,
            stage,
            streams: (0..executors).map(|_| None).collect(),
            readers: (0..executors).map(|_| None).collect(),
            events_tx,
            events_rx,
            closing: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The host (index into the configured list) executor `eid` is
    /// placed on.
    fn host_index(&self, eid: usize) -> usize {
        eid % self.hosts.len()
    }
}

/// Record one uploaded spill frame into the driver-side stage.
/// Best-effort durability: a malformed frame or a failed write degrades
/// `--resume` coverage, never the run itself.
fn record_spill_frame(stage: &StageCheckpoint, eid: usize, frame: &Json) {
    let (Ok(start), Ok(end)) = (
        frame.get("start").and_then(|v| v.as_usize()),
        frame.get("end").and_then(|v| v.as_usize()),
    ) else {
        eprintln!("warning: dropping malformed spill frame from executor {eid}");
        return;
    };
    let attempt = frame.usize_or("attempt", 1);
    let lines: Vec<String> = match frame.get("rows") {
        Ok(Json::Arr(rows)) => rows.iter().map(|r| r.to_string()).collect(),
        _ => {
            eprintln!("warning: dropping spill frame without rows from executor {eid}");
            return;
        }
    };
    if let Err(e) = stage.record_task(start, end, attempt, eid, &lines) {
        eprintln!("warning: recording uploaded spill [{start}, {end}) failed: {e:#}");
    }
}

impl ExecutorBackend for RemoteBackend {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn spawn_executor(&mut self, eid: usize) -> Result<()> {
        let host = self.hosts[self.host_index(eid)].clone();
        let stream = TcpStream::connect(&host)
            .with_context(|| format!("connecting to serve-worker host {host}"))?;
        let _ = stream.set_nodelay(true);
        let mut reader_stream =
            stream.try_clone().context("cloning worker socket for the reader")?;
        // The read timeout *is* the heartbeat check: serve workers emit a
        // heartbeat frame every second, so a full window with no frame at
        // all means the connection (or the host) is gone.
        reader_stream
            .set_read_timeout(Some(self.heartbeat_timeout))
            .context("setting heartbeat read timeout")?;

        // Handshake: ship the plan once, spliced verbatim.
        let hello = format!(
            "{{\"type\":\"hello\",\"executor_id\":{eid},\"batch_size\":{},\"plan\":{}}}",
            self.batch_size, self.plan_text
        );
        write_frame_bytes(&mut &stream, hello.as_bytes())
            .with_context(|| format!("writing hello frame to {host}"))?;

        let events = self.events_tx.clone();
        let closing = self.closing.clone();
        let stage = self.stage.clone();
        let timeout = self.heartbeat_timeout;
        let reader = std::thread::Builder::new()
            .name(format!("slleval-remote-rx-{eid}"))
            .spawn(move || loop {
                match read_frame(&mut reader_stream) {
                    Ok(Some(frame)) => match frame.str_or("type", "") {
                        "heartbeat" => continue,
                        "spill" => {
                            if let Some(stage) = stage.as_deref() {
                                record_spill_frame(stage, eid, &frame);
                            }
                        }
                        _ => {
                            if let Some(event) = worker_frame_to_event(eid, &frame) {
                                if events.send(event).is_err() {
                                    return;
                                }
                            }
                        }
                    },
                    Ok(None) => {
                        if !closing.load(Ordering::Relaxed) {
                            let _ = events.send(ExecutorEvent::Died {
                                executor_id: eid,
                                detail: format!("{host} closed the connection"),
                            });
                        }
                        return;
                    }
                    Err(e) => {
                        if !closing.load(Ordering::Relaxed) {
                            let detail = if is_timeout(&e) {
                                format!(
                                    "heartbeat timeout: no frame from {host} in {timeout:?}"
                                )
                            } else {
                                format!("connection to {host} failed: {e:#}")
                            };
                            let _ = events
                                .send(ExecutorEvent::Died { executor_id: eid, detail });
                        }
                        return;
                    }
                }
            })
            .context("spawning remote reader thread")?;

        self.streams[eid] = Some(stream);
        self.readers[eid] = Some(reader);
        Ok(())
    }

    fn submit(&mut self, eid: usize, spec: &TaskSpec) -> Result<()> {
        let stream = self.streams[eid].as_ref().context("executor not connected")?;
        write_frame(&mut &*stream, &spec.to_json())
            .with_context(|| format!("submitting task to remote executor {eid}"))
    }

    fn poll(&mut self, timeout: Duration) -> Option<ExecutorEvent> {
        self.events_rx.recv_timeout(timeout).ok()
    }

    fn alive(&self, eid: usize) -> bool {
        self.streams[eid].is_some()
            && self.readers[eid].as_ref().map(|r| !r.is_finished()).unwrap_or(false)
    }

    fn shutdown(&mut self) {
        self.closing.store(true, Ordering::Relaxed);
        let shutdown_msg = Json::obj(vec![("type", Json::str("shutdown"))]);
        for stream in self.streams.iter_mut() {
            if let Some(s) = stream.take() {
                let _ = write_frame(&mut &s, &shutdown_msg);
                // Closing the write half unblocks a worker mid-read even
                // if it missed the frame; the reader thread sees EOF (or
                // its read timeout) and exits.
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        for reader in self.readers.iter_mut() {
            if let Some(r) = reader.take() {
                let _ = r.join();
            }
        }
    }

    fn host_of(&self, eid: usize) -> Option<usize> {
        Some(self.host_index(eid))
    }
}

impl Drop for RemoteBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::plan::{MetricPlan, PlanEnv, PlanWork};

    fn trivial_plan() -> TaskPlan {
        TaskPlan {
            work: PlanWork::MetricScore(MetricPlan {
                metric: crate::config::MetricConfig::new("exact_match", "lexical"),
                examples: Vec::new(),
            }),
            env: PlanEnv::default(),
            stage: None,
            fault: None,
        }
    }

    #[test]
    fn executors_round_robin_over_hosts() {
        let hosts = vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()];
        let b = RemoteBackend::new(
            &trivial_plan(),
            7,
            5,
            hosts,
            DEFAULT_HEARTBEAT_TIMEOUT,
            None,
        )
        .unwrap();
        assert_eq!(b.host_of(0), Some(0));
        assert_eq!(b.host_of(1), Some(1));
        assert_eq!(b.host_of(2), Some(2));
        assert_eq!(b.host_of(3), Some(0));
        assert_eq!(b.host_of(6), Some(0));
    }

    #[test]
    fn empty_host_list_is_rejected() {
        let err = RemoteBackend::new(
            &trivial_plan(),
            2,
            5,
            Vec::new(),
            DEFAULT_HEARTBEAT_TIMEOUT,
            None,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("hosts"), "{err}");
    }

    #[test]
    fn heartbeat_timeout_parses_fractional_seconds() {
        assert_eq!(parse_heartbeat_timeout(Some("0.25")), Duration::from_secs_f64(0.25));
        assert_eq!(parse_heartbeat_timeout(Some(" 2 ")), Duration::from_secs(2));
        assert_eq!(parse_heartbeat_timeout(Some("nonsense")), DEFAULT_HEARTBEAT_TIMEOUT);
        assert_eq!(parse_heartbeat_timeout(Some("-1")), DEFAULT_HEARTBEAT_TIMEOUT);
        assert_eq!(parse_heartbeat_timeout(None), DEFAULT_HEARTBEAT_TIMEOUT);
    }
}
