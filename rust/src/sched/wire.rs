//! The executor wire codec: 4-byte big-endian length + UTF-8 JSON frames.
//!
//! One implementation shared by every transport that speaks the worker
//! protocol — the [`ProcessBackend`](super::backend::ProcessBackend)
//! stdin/stdout pipes, the [`RemoteBackend`](super::remote::RemoteBackend)
//! TCP sockets, and the `slleval worker` / `slleval serve-worker` sides
//! of both. The codec is deliberately strict: a frame larger than
//! [`MAX_FRAME_BYTES`] or a stream that ends mid-frame is an *error*, not
//! a hang or a panic — the reading side surfaces it and the driver folds
//! it into the executor-death machinery.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::sync::{Arc, Mutex};

/// Frames larger than this are a protocol error, not an allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Write one length-prefixed frame from already-serialized JSON text.
/// Oversized frames fail here with a clear error instead of being
/// rejected (or, past u32, silently desynchronized) reader-side.
pub fn write_frame_bytes<W: Write>(w: &mut W, bytes: &[u8]) -> std::io::Result<()> {
    if bytes.len() > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!(
                "frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte protocol limit \
                 (plan payload too large for one executor handshake)",
                bytes.len()
            ),
        ));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Write one length-prefixed JSON frame.
pub fn write_frame<W: Write>(w: &mut W, v: &Json) -> std::io::Result<()> {
    write_frame_bytes(w, v.to_string().as_bytes())
}

/// Read one length-prefixed JSON frame. `Ok(None)` is a clean EOF at a
/// frame boundary; a torn frame or oversized length is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0usize;
    while filled < 4 {
        match r.read(&mut len_buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid-frame (length prefix truncated)"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame length"),
        }
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte protocol limit");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading frame body")?;
    let text = String::from_utf8(body).context("frame is not UTF-8")?;
    Ok(Some(Json::parse(&text).map_err(anyhow::Error::msg)?))
}

/// A shared, lockable frame destination. Several producers interleave
/// *whole* frames onto one stream — a worker's result loop, its
/// heartbeat thread, and the spill-upload path inside a running task —
/// so every write takes the lock for one complete frame.
pub type SharedWriter = Arc<Mutex<Box<dyn Write + Send>>>;

/// Write one frame through a [`SharedWriter`], atomically with respect
/// to the other producers on the same stream.
pub fn write_frame_shared(w: &SharedWriter, v: &Json) -> std::io::Result<()> {
    let mut guard = w.lock().unwrap_or_else(|poison| poison.into_inner());
    write_frame(&mut *guard, v)
}

/// Whether an error from [`read_frame`] is a read *timeout* (the socket's
/// `read_timeout` elapsed with no frame — a missed heartbeat) rather than
/// a closed or broken connection.
pub fn is_timeout(e: &anyhow::Error) -> bool {
    e.root_cause()
        .downcast_ref::<std::io::Error>()
        .map(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        })
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip() {
        let v = Json::obj(vec![
            ("type", Json::str("task")),
            ("payload", Json::arr(vec![Json::num(1.0), Json::str("two")])),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        write_frame(&mut buf, &Json::str("second")).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap(), v);
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap(), Json::str("second"));
        assert!(read_frame(&mut cursor).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn torn_frame_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::str("x")).unwrap();
        buf.truncate(buf.len() - 1);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
        // Truncated length prefix.
        let mut cursor = std::io::Cursor::new(vec![0u8, 0, 0]);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_an_error_not_an_allocation() {
        // A length prefix past the cap must be rejected before the body
        // buffer is allocated (a malicious/corrupt peer cannot OOM the
        // driver with 4 bytes).
        let mut buf = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(b"junk");
        let mut cursor = std::io::Cursor::new(buf);
        let err = read_frame(&mut cursor).unwrap_err();
        assert!(format!("{err}").contains("protocol limit"), "{err}");
    }

    /// A `Write` that mirrors everything into a shared buffer the test
    /// can read back out after the boxed trait object swallows it.
    struct Probe(Arc<Mutex<Vec<u8>>>);
    impl Write for Probe {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn shared_writer_produces_a_parseable_frame_stream() {
        let bytes = Arc::new(Mutex::new(Vec::new()));
        let sink: SharedWriter = Arc::new(Mutex::new(Box::new(Probe(bytes.clone()))));
        write_frame_shared(&sink, &Json::str("a")).unwrap();
        write_frame_shared(&sink, &Json::str("b")).unwrap();
        let captured = bytes.lock().unwrap().clone();
        let mut cursor = std::io::Cursor::new(captured);
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap(), Json::str("a"));
        assert_eq!(read_frame(&mut cursor).unwrap().unwrap(), Json::str("b"));
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }
}
