//! Spark-style task scheduler: work stealing, speculative execution, and
//! fault tolerance (paper §3.1, extended per §6.1 "stragglers").
//!
//! The static range partitioning in [`crate::engine`] assigns one fixed
//! slice per executor, so a single slow partition (skewed prompt lengths,
//! rate-limit backoff, provider latency spikes) stalls the whole job. This
//! module replaces it with dynamic task scheduling:
//!
//! - the DataFrame is split into **many more tasks than executors**
//!   (`tasks_per_executor`), each a contiguous row range;
//! - executors pull from per-executor deques and **steal** from the
//!   longest queue when their own runs dry;
//! - once ≥ `speculation_quantile` of tasks have finished, idle executors
//!   **speculatively re-execute** the longest-running in-flight task
//!   (each task is duplicated at most once; first completion wins);
//! - failed tasks are **retried** on a different executor up to
//!   `max_task_attempts`, and executors are **blacklisted** after
//!   `blacklist_after` failures (their queues are redistributed);
//! - oversized tasks are **adaptively split** while running: when idle
//!   executors exist and a task's own observed batch latency projects its
//!   remaining work past the target per-task wall time, its tail half is
//!   re-enqueued as a fresh task;
//! - a **UDF panic** is caught and handled like an erroring UDF: the
//!   attempt fails and the task is retried on another executor (repeat
//!   offenders are blacklisted). Only executor *init* panics still shut
//!   the whole pool down;
//! - completed tasks can be **checkpointed** through a [`TaskSink`]
//!   (crash-safe spill via [`crate::checkpoint`]), and a resumed run can
//!   inject **restored ranges** as pre-completed tasks so only the gaps
//!   re-execute ([`run_scheduled_ext`]);
//! - an optional **abort flag** stops the job between batches (cost
//!   budgets, Ctrl-C): in-flight work is abandoned, already-completed
//!   tasks stay checkpointed.
//!
//! Output is **row-order exact**: tasks cover disjoint contiguous ranges
//! whose results are reassembled by range start, so a scheduled job is
//! byte-identical to the static engine's output regardless of the
//! schedule. [`crate::engine::run_partitioned`] is now a thin wrapper over
//! this scheduler with [`SchedulerConfig::legacy`] (one pinned task per
//! executor, no stealing/speculation/retry), preserving the original
//! semantics bit for bit.

pub mod backend;
pub mod plan;
pub mod remote;
pub mod wire;

use crate::checkpoint::StageCheckpoint;
use crate::data::DataFrame;
use crate::engine::{BatchSlice, ExecutorStats, Progress};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// `blacklist_after` values at or beyond this serialize as the sentinel
/// and parse back to `usize::MAX` ("never blacklist") — f64 JSON numbers
/// cannot represent `usize::MAX` exactly.
const BLACKLIST_NEVER_SENTINEL: usize = 1 << 52;

/// Scheduler behaviour knobs (serialized inside the task config).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Tasks created per executor (task granularity). 1 reproduces static
    /// range partitioning; larger values enable load balancing.
    pub tasks_per_executor: usize,
    /// Idle executors steal queued tasks from busy ones.
    pub work_stealing: bool,
    /// Re-execute stragglers once most tasks have finished.
    pub speculation: bool,
    /// Fraction of tasks that must be complete before speculation starts.
    pub speculation_quantile: f64,
    /// Attempts per task before the job fails (1 = no retry).
    pub max_task_attempts: usize,
    /// Task failures on one executor before it is blacklisted.
    pub blacklist_after: usize,
    /// Split oversized in-flight tasks when executors go idle.
    pub adaptive_split: bool,
    /// Target per-task wall time for adaptive splitting, seconds.
    /// `0.0` derives the target from the observed mean batch latency.
    pub target_task_secs: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            tasks_per_executor: 4,
            work_stealing: true,
            speculation: true,
            speculation_quantile: 0.75,
            max_task_attempts: 3,
            blacklist_after: 3,
            adaptive_split: true,
            target_task_secs: 0.0,
        }
    }
}

impl SchedulerConfig {
    /// The static-engine compatibility preset: one pinned task per
    /// executor, no stealing, no speculation, no retry, no splitting —
    /// exactly the semantics of the original `run_partitioned`.
    pub fn legacy() -> Self {
        Self {
            tasks_per_executor: 1,
            work_stealing: false,
            speculation: false,
            speculation_quantile: 1.0,
            max_task_attempts: 1,
            blacklist_after: usize::MAX,
            adaptive_split: false,
            target_task_secs: 0.0,
        }
    }

    /// Validate invariants (called from `EvalTask::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.tasks_per_executor == 0 {
            bail!("scheduler.tasks_per_executor must be >= 1");
        }
        if self.max_task_attempts == 0 {
            bail!("scheduler.max_task_attempts must be >= 1");
        }
        if self.blacklist_after == 0 {
            bail!("scheduler.blacklist_after must be >= 1");
        }
        if !(self.speculation_quantile > 0.0 && self.speculation_quantile <= 1.0) {
            bail!("scheduler.speculation_quantile must be in (0, 1]");
        }
        if self.target_task_secs < 0.0 {
            bail!("scheduler.target_task_secs must be >= 0");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tasks_per_executor", Json::num(self.tasks_per_executor as f64)),
            ("work_stealing", Json::Bool(self.work_stealing)),
            ("speculation", Json::Bool(self.speculation)),
            ("speculation_quantile", Json::num(self.speculation_quantile)),
            ("max_task_attempts", Json::num(self.max_task_attempts as f64)),
            (
                "blacklist_after",
                // usize::MAX does not survive f64; serialize anything at or
                // beyond the sentinel as the sentinel, and from_json maps
                // it back to usize::MAX ("never") so round-trips are exact.
                Json::num(self.blacklist_after.min(BLACKLIST_NEVER_SENTINEL) as f64),
            ),
            ("adaptive_split", Json::Bool(self.adaptive_split)),
            ("target_task_secs", Json::num(self.target_task_secs)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = SchedulerConfig::default();
        let blacklist_after = v.usize_or("blacklist_after", d.blacklist_after);
        let cfg = SchedulerConfig {
            tasks_per_executor: v.usize_or("tasks_per_executor", d.tasks_per_executor),
            work_stealing: v.bool_or("work_stealing", d.work_stealing),
            speculation: v.bool_or("speculation", d.speculation),
            speculation_quantile: v.f64_or("speculation_quantile", d.speculation_quantile),
            max_task_attempts: v.usize_or("max_task_attempts", d.max_task_attempts),
            blacklist_after: if blacklist_after >= BLACKLIST_NEVER_SENTINEL {
                usize::MAX
            } else {
                blacklist_after
            },
            adaptive_split: v.bool_or("adaptive_split", d.adaptive_split),
            target_task_secs: v.f64_or("target_task_secs", d.target_task_secs),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// How one task attempt ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOutcome {
    /// First completion of the task: its rows are in the job output.
    Won,
    /// Completed after a twin already won (wasted speculative work).
    Lost,
    /// The UDF returned an error; the task was retried or the job failed.
    Failed,
    /// Abandoned mid-run because a twin completed first.
    Abandoned,
}

impl TaskOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            TaskOutcome::Won => "won",
            TaskOutcome::Lost => "lost",
            TaskOutcome::Failed => "failed",
            TaskOutcome::Abandoned => "abandoned",
        }
    }
}

/// One task attempt in the scheduler timeline (driver-side telemetry).
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task_id: usize,
    /// Row range covered at completion time (post-split).
    pub start: usize,
    pub end: usize,
    pub executor_id: usize,
    /// 1-based attempt number (speculative twins share the original's id).
    pub attempt: usize,
    pub speculative: bool,
    /// Seconds since job start.
    pub started_at: f64,
    pub finished_at: f64,
    pub outcome: TaskOutcome,
}

impl TaskRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task_id", Json::num(self.task_id as f64)),
            ("start", Json::num(self.start as f64)),
            ("end", Json::num(self.end as f64)),
            ("executor_id", Json::num(self.executor_id as f64)),
            ("attempt", Json::num(self.attempt as f64)),
            ("speculative", Json::Bool(self.speculative)),
            ("started_at", Json::num(self.started_at)),
            ("finished_at", Json::num(self.finished_at)),
            ("outcome", Json::str(self.outcome.as_str())),
        ])
    }
}

/// Aggregate scheduler telemetry for one job.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Final task count (including adaptive-split children).
    pub tasks: usize,
    /// Tasks executed by an executor other than their initial assignee.
    pub steals: usize,
    /// Speculative twins launched / twins that finished first.
    pub speculative_launched: usize,
    pub speculative_wins: usize,
    /// Adaptive splits performed.
    pub splits: usize,
    /// Task attempts beyond each task's first.
    pub retries: usize,
    /// Executors that *died* (process exit, pipe EOF, injected kill) as
    /// opposed to failing tasks: only backend-scheduled jobs
    /// ([`backend::run_plan`]) can observe these — the in-process
    /// scheduler cannot outlive an executor crash. Dead executors also
    /// appear in `blacklisted_executors` (they take no further work).
    pub executor_deaths: usize,
    /// Whole failure domains lost (remote backend: a `serve-worker` host
    /// whose connections dropped — each of its executors also counts one
    /// `executor_death`). Always 0 for single-host backends.
    pub host_deaths: usize,
    pub blacklisted_executors: Vec<usize>,
    /// Tasks/rows restored from a run checkpoint instead of re-executed
    /// (paid-for work carried over by `--resume`).
    pub restored_tasks: usize,
    pub restored_rows: usize,
    /// Rows processed by losing or abandoned attempts (duplicated work).
    pub wasted_rows: usize,
    /// Rows this job actually evaluated (= all rows, unless a wave gate
    /// settled the job early — then the certified prefix length).
    pub rows_evaluated: usize,
    /// Rows deliberately never issued because a wave gate stopped the job
    /// early (`rows_evaluated + rows_saved` = the frame size). 0 unless
    /// adaptive stopping is enabled.
    pub rows_saved: usize,
    /// Wave-gate consults performed (0 = ungated classic run).
    pub waves: usize,
    /// Wall-time statistics over winning task attempts.
    pub longest_task_secs: f64,
    pub mean_task_secs: f64,
    /// longest/mean winning-task wall time (1.0 = perfectly balanced).
    pub skew_ratio: f64,
}

impl SchedulerStats {
    /// Fold another job's telemetry into this one (streaming evaluation
    /// accumulates per-chunk scheduler stats into a run total).
    pub fn merge(&mut self, other: &SchedulerStats) {
        let tasks_before = self.tasks;
        self.tasks += other.tasks;
        self.steals += other.steals;
        self.speculative_launched += other.speculative_launched;
        self.speculative_wins += other.speculative_wins;
        self.splits += other.splits;
        self.retries += other.retries;
        self.executor_deaths += other.executor_deaths;
        self.host_deaths += other.host_deaths;
        for &e in &other.blacklisted_executors {
            if !self.blacklisted_executors.contains(&e) {
                self.blacklisted_executors.push(e);
            }
        }
        self.blacklisted_executors.sort_unstable();
        self.restored_tasks += other.restored_tasks;
        self.restored_rows += other.restored_rows;
        self.wasted_rows += other.wasted_rows;
        self.rows_evaluated += other.rows_evaluated;
        self.rows_saved += other.rows_saved;
        self.waves += other.waves;
        self.longest_task_secs = self.longest_task_secs.max(other.longest_task_secs);
        // Task-count-weighted mean of winning task wall times.
        if self.tasks > 0 {
            self.mean_task_secs = (self.mean_task_secs * tasks_before as f64
                + other.mean_task_secs * other.tasks as f64)
                / self.tasks as f64;
        }
        self.skew_ratio = if self.mean_task_secs > 0.0 {
            self.longest_task_secs / self.mean_task_secs
        } else {
            1.0
        };
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tasks", Json::num(self.tasks as f64)),
            ("steals", Json::num(self.steals as f64)),
            ("speculative_launched", Json::num(self.speculative_launched as f64)),
            ("speculative_wins", Json::num(self.speculative_wins as f64)),
            ("splits", Json::num(self.splits as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("executor_deaths", Json::num(self.executor_deaths as f64)),
            ("host_deaths", Json::num(self.host_deaths as f64)),
            (
                "blacklisted_executors",
                Json::arr(
                    self.blacklisted_executors.iter().map(|&e| Json::num(e as f64)).collect(),
                ),
            ),
            ("restored_tasks", Json::num(self.restored_tasks as f64)),
            ("restored_rows", Json::num(self.restored_rows as f64)),
            ("wasted_rows", Json::num(self.wasted_rows as f64)),
            ("rows_evaluated", Json::num(self.rows_evaluated as f64)),
            ("rows_saved", Json::num(self.rows_saved as f64)),
            ("waves", Json::num(self.waves as f64)),
            ("longest_task_secs", Json::num(self.longest_task_secs)),
            ("mean_task_secs", Json::num(self.mean_task_secs)),
            ("skew_ratio", Json::num(self.skew_ratio)),
        ])
    }
}

/// Scheduled-job outcome: per-row outputs in row order + telemetry.
#[derive(Debug)]
pub struct SchedOutput<T> {
    pub rows: Vec<T>,
    pub executors: Vec<ExecutorStats>,
    pub sched: SchedulerStats,
    pub timeline: Vec<TaskRecord>,
}

/// Where and how to spill one completed task's rows (checkpointing).
pub struct TaskSink<'a, T> {
    /// Stage store the manifest + data files go to.
    pub stage: &'a StageCheckpoint,
    /// Row encoder: one JSON value per row, serialized one per line.
    pub encode: &'a (dyn Fn(&T) -> Json + Sync),
}

/// Bridge between the scheduler and the run-checkpoint store
/// ([`crate::checkpoint`]): ranges restored from a previous run enter the
/// job as pre-completed tasks, and freshly completed tasks are persisted
/// through the sink as they win.
pub struct TaskCheckpoint<'a, T> {
    /// Completed `(start, end, rows)` ranges restored from a prior run's
    /// manifest. Must be disjoint and in-bounds, with exactly
    /// `end - start` rows each; only the uncovered gaps are executed.
    pub restored: Vec<(usize, usize, Vec<T>)>,
    /// Sink persisting freshly completed tasks (`None` = restore-only).
    pub sink: Option<TaskSink<'a, T>>,
}

/// A wave gate's verdict after inspecting a completed row prefix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaveDecision {
    /// Keep going: release the next wave of tasks.
    Continue,
    /// The answer is certified — settle the job at the current boundary.
    /// Not-yet-issued tasks are cancelled (their rows count as
    /// `rows_saved`); in-flight attempts wind down undisturbed.
    Stop,
}

/// Adaptive-stopping hook for scheduled jobs (see DESIGN.md "Adaptive
/// stopping"): rows are issued in waves, and after each wave completes
/// the gate inspects the exact in-order row prefix and decides whether
/// the job keeps going or settles early.
///
/// Wave boundaries are `first, first + step, first + 2·step, …` — pure
/// config, never wall clock — and tasks are carved so none spans a
/// boundary. `decide(wave, prefix)` runs once per boundary (single
/// flight, under the scheduler lock — at that moment every row below the
/// boundary is complete and no other work is runnable, so the lock is
/// not contended), with `prefix.len()` equal to the boundary. A
/// `decide` error fails the job like a fatal scheduler error.
pub struct WaveGate<'a, T> {
    /// First consult boundary (clamped to `[1, total_rows]`).
    pub first: usize,
    /// Rows released per wave after the first (min 1).
    pub step: usize,
    /// The stopping rule. `wave` is 0-based consult index.
    pub decide: &'a (dyn Fn(usize, &[&T]) -> Result<WaveDecision> + Sync),
}

/// A queued task attempt. Row ranges live in `SchedState::ranges` so
/// adaptive splits apply to whichever attempt eventually runs.
#[derive(Debug, Clone, Copy)]
struct TaskItem {
    id: usize,
    speculative: bool,
}

/// In-flight attempt registry entry.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    task_id: usize,
    executor_id: usize,
    speculative: bool,
    started_secs: f64,
}

struct SchedState<T> {
    /// Per-executor task queues (own: pop_front; steal: pop_back).
    deques: Vec<VecDeque<TaskItem>>,
    /// Current row range per task id (end shrinks on split).
    ranges: Vec<(usize, usize)>,
    /// First-completion flag per task id.
    completed: Vec<bool>,
    completed_tasks: usize,
    /// Failed attempts per task id.
    attempts_failed: Vec<usize>,
    /// Tasks injected pre-completed from a run checkpoint (these are
    /// excluded from the speculation-quantile bookkeeping, which reasons
    /// about *this* run's progress).
    restored_tasks: usize,
    /// Task already duplicated (speculation) — also seals it against splits.
    speculated: Vec<bool>,
    /// Winning output per task id.
    results: Vec<Option<Vec<T>>>,
    inflight: Vec<InFlight>,
    rows_done: usize,
    total_rows: usize,
    /// Wave gating: tasks at or past `boundary` wait here (with their
    /// home deque) until the gate releases the next wave. Ascending by
    /// range start. Empty and inert for ungated jobs.
    deferred: VecDeque<(usize, TaskItem)>,
    /// Rows `[0, boundary)` are issuable; `total_rows` when ungated.
    boundary: usize,
    /// Early-settle row boundary once the gate said [`WaveDecision::Stop`].
    settled: Option<usize>,
    /// Gate consults performed so far.
    waves: usize,
    failures_per_executor: Vec<usize>,
    blacklisted: Vec<bool>,
    /// Executors currently parked waiting for work.
    idle: usize,
    fatal: Option<anyhow::Error>,
    /// EWMA of batch wall time across all executors (split heuristic).
    ewma_batch_secs: f64,
    timeline: Vec<TaskRecord>,
    steals: usize,
    speculative_launched: usize,
    speculative_wins: usize,
    splits: usize,
    retries: usize,
}

impl<T> SchedState<T> {
    fn done(&self) -> bool {
        self.fatal.is_some() || self.settled.is_some() || self.rows_done == self.total_rows
    }

    fn new_task(&mut self, start: usize, end: usize) -> usize {
        let id = self.ranges.len();
        self.ranges.push((start, end));
        self.completed.push(false);
        self.attempts_failed.push(0);
        self.speculated.push(false);
        self.results.push(None);
        id
    }
}

/// What a worker decided to do after consulting the shared state.
enum Decision {
    Run { item: TaskItem, attempt: usize, start: usize, end: usize, started_secs: f64 },
    Wait,
    Exit,
}

/// Shuts the pool down if a worker thread unwinds. UDF panics are caught
/// per batch and converted into retryable task failures, so the unwinds
/// that reach this guard are executor *init* panics and scheduler-internal
/// bugs — cases where executor-local state never existed or the pool
/// itself is suspect, and aborting the job is the only safe move. Without
/// the guard, the dead worker's in-flight task would never settle and the
/// scoped join would block forever; with it, the survivors exit on
/// `fatal` and the panicked thread's join handle surfaces the panic.
struct PanicGuard<'a, T> {
    shared: &'a Mutex<SchedState<T>>,
    work_ready: &'a Condvar,
}

impl<T> Drop for PanicGuard<'_, T> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // The mutex may be poisoned by the panicking thread itself;
            // the state write is still sound (counters + an error slot).
            let mut state = match self.shared.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if state.fatal.is_none() {
                state.fatal = Some(anyhow::anyhow!("executor thread panicked"));
            }
            self.work_ready.notify_all();
        }
    }
}

/// Run a batch UDF over `df` with `executors` threads under `cfg`.
///
/// Semantics match [`crate::engine::run_partitioned`]: `init(executor_id)`
/// builds executor-local state once per executor thread; `process(state,
/// df, slice)` maps one batch to one output per row. Outputs are returned
/// in row order regardless of the schedule. `progress`, when supplied, is
/// advanced by each task's row count at first completion (driver-side
/// progress for streaming jobs).
pub fn run_scheduled<T, S, FI, FP>(
    df: &DataFrame,
    executors: usize,
    batch_size: usize,
    cfg: &SchedulerConfig,
    progress: Option<&Progress>,
    init: FI,
    process: FP,
) -> Result<SchedOutput<T>>
where
    T: Send,
    S: Send,
    FI: Fn(usize) -> Result<S> + Sync,
    FP: Fn(&mut S, &DataFrame, BatchSlice) -> Result<Vec<T>> + Sync,
{
    run_scheduled_ext(df, executors, batch_size, cfg, progress, None, None, init, process)
}

/// [`run_scheduled`] plus durability hooks: `checkpoint` injects ranges
/// restored from a previous run as pre-completed tasks (only uncovered
/// gaps execute) and spills freshly completed tasks through its sink;
/// `abort`, when set to `true` by any thread, stops the job between
/// batches with a "run aborted" error while keeping everything already
/// checkpointed.
#[allow(clippy::too_many_arguments)]
pub fn run_scheduled_ext<T, S, FI, FP>(
    df: &DataFrame,
    executors: usize,
    batch_size: usize,
    cfg: &SchedulerConfig,
    progress: Option<&Progress>,
    checkpoint: Option<TaskCheckpoint<'_, T>>,
    abort: Option<&AtomicBool>,
    init: FI,
    process: FP,
) -> Result<SchedOutput<T>>
where
    T: Send,
    S: Send,
    FI: Fn(usize) -> Result<S> + Sync,
    FP: Fn(&mut S, &DataFrame, BatchSlice) -> Result<Vec<T>> + Sync,
{
    run_scheduled_wave(
        df, executors, batch_size, cfg, progress, checkpoint, abort, None, init, process,
    )
}

/// [`run_scheduled_ext`] plus adaptive stopping: with a [`WaveGate`],
/// tasks are carved so none spans a wave boundary and only the first
/// wave is issuable; each time the completed in-order prefix reaches the
/// boundary the gate decides whether to release the next wave or settle
/// the job early. An early-settled job returns the exact `[0, boundary)`
/// prefix, cancels every not-yet-issued task (accounted as
/// `rows_saved`), and lets in-flight attempts wind down undisturbed.
/// `gate: None` is byte-for-byte the ungated scheduler.
#[allow(clippy::too_many_arguments)]
pub fn run_scheduled_wave<T, S, FI, FP>(
    df: &DataFrame,
    executors: usize,
    batch_size: usize,
    cfg: &SchedulerConfig,
    progress: Option<&Progress>,
    checkpoint: Option<TaskCheckpoint<'_, T>>,
    abort: Option<&AtomicBool>,
    gate: Option<WaveGate<'_, T>>,
    init: FI,
    process: FP,
) -> Result<SchedOutput<T>>
where
    T: Send,
    S: Send,
    FI: Fn(usize) -> Result<S> + Sync,
    FP: Fn(&mut S, &DataFrame, BatchSlice) -> Result<Vec<T>> + Sync,
{
    cfg.validate()?;
    let executors = executors.max(1);
    let batch_size = batch_size.max(1);
    let total_rows = df.len();
    // lint:allow(determinism): t0 anchors wall-clock task-timeline telemetry
    let t0 = Instant::now();

    let (mut restored, sink) = match checkpoint {
        Some(c) => (c.restored, c.sink),
        None => (Vec::new(), None),
    };
    let sink = sink.as_ref();
    restored.sort_by_key(|(start, _, _)| *start);
    {
        let mut cursor = 0usize;
        for (start, end, rows) in &restored {
            anyhow::ensure!(
                start < end && *end <= total_rows,
                "restored range [{start}, {end}) out of bounds for {total_rows} rows"
            );
            anyhow::ensure!(*start >= cursor, "restored ranges overlap at row {start}");
            anyhow::ensure!(
                rows.len() == end - start,
                "restored range [{start}, {end}) carries {} rows",
                rows.len()
            );
            cursor = *end;
        }
    }
    let restored_spans: Vec<(usize, usize)> =
        restored.iter().map(|(start, end, _)| (*start, *end)).collect();

    let n_slots = executors * cfg.tasks_per_executor;
    let mut state = SchedState::<T> {
        deques: (0..executors).map(|_| VecDeque::new()).collect(),
        ranges: Vec::new(),
        completed: Vec::new(),
        completed_tasks: 0,
        attempts_failed: Vec::new(),
        restored_tasks: 0,
        speculated: Vec::new(),
        results: Vec::new(),
        inflight: Vec::new(),
        rows_done: 0,
        total_rows,
        deferred: VecDeque::new(),
        boundary: match &gate {
            Some(g) => g.first.max(1).min(total_rows),
            None => total_rows,
        },
        settled: None,
        waves: 0,
        failures_per_executor: vec![0; executors],
        blacklisted: vec![false; executors],
        idle: 0,
        fatal: None,
        ewma_batch_secs: 0.0,
        timeline: Vec::new(),
        steals: 0,
        speculative_launched: 0,
        speculative_wins: 0,
        splits: 0,
        retries: 0,
    };

    // Restored ranges enter as already-won tasks: their rows are final,
    // they hold no queue slot, and the per-run progress/speculation
    // bookkeeping never sees them as live work.
    let mut restored_rows = 0usize;
    for (start, end, rows) in restored {
        let id = state.new_task(start, end);
        state.completed[id] = true;
        state.completed_tasks += 1;
        state.restored_tasks += 1;
        state.results[id] = Some(rows);
        state.rows_done += end - start;
        restored_rows += end - start;
        if let Some(p) = progress {
            p.add(end - start);
        }
    }

    if restored_spans.is_empty() {
        // Carve the frame into tasks: contiguous near-equal ranges (empty
        // slots are skipped), assigned contiguously so the initial layout
        // matches the static engine's `partition_ranges` exactly when
        // tasks_per_executor == 1.
        for (slot, range) in df.partition_ranges(n_slots).into_iter().enumerate() {
            if range.is_empty() {
                continue;
            }
            let id = state.new_task(range.start, range.end);
            let home = slot * executors / n_slots;
            state.deques[home].push_back(TaskItem { id, speculative: false });
        }
    } else {
        // Carve only the gaps between restored ranges, splitting each gap
        // into a slot share proportional to its size.
        let mut gaps: Vec<(usize, usize)> = Vec::new();
        let mut cursor = 0usize;
        for &(start, end) in &restored_spans {
            if start > cursor {
                gaps.push((cursor, start));
            }
            cursor = end;
        }
        if cursor < total_rows {
            gaps.push((cursor, total_rows));
        }
        let total_gap: usize = gaps.iter().map(|(s, e)| e - s).sum();
        let mut slot = 0usize;
        for &(gap_start, gap_end) in &gaps {
            let len = gap_end - gap_start;
            let parts = (len * n_slots).div_ceil(total_gap).clamp(1, len);
            let base = len / parts;
            let rem = len % parts;
            let mut start = gap_start;
            for i in 0..parts {
                let end = start + base + usize::from(i < rem);
                let id = state.new_task(start, end);
                let home = (slot * executors / n_slots).min(executors - 1);
                state.deques[home].push_back(TaskItem { id, speculative: false });
                slot += 1;
                start = end;
            }
        }
    }

    if let Some(g) = &gate {
        // Wave alignment: split every queued task at each wave boundary
        // it spans (boundaries come from config only — `first`, then
        // `step` apart — never wall clock), then park the pieces at or
        // past the first boundary until the gate releases their wave.
        let step = g.step.max(1);
        let mut parked: Vec<(usize, TaskItem)> = Vec::new();
        let first = state.boundary;
        for home in 0..executors {
            let items: Vec<TaskItem> = state.deques[home].drain(..).collect();
            for item in items {
                let end = state.ranges[item.id].1;
                // Shrink the original task to its first aligned piece and
                // spawn children for the rest.
                let mut ids = vec![item.id];
                let mut cursor = state.ranges[item.id].0;
                loop {
                    let next_b = if cursor < first {
                        first
                    } else {
                        first + (cursor - first) / step * step + step
                    };
                    if next_b >= end {
                        break;
                    }
                    state.ranges[*ids.last().unwrap()].1 = next_b;
                    let child = state.new_task(next_b, end);
                    ids.push(child);
                    cursor = next_b;
                }
                for id in ids {
                    let piece = TaskItem { id, speculative: false };
                    if state.ranges[id].0 < first {
                        state.deques[home].push_back(piece);
                    } else {
                        parked.push((home, piece));
                    }
                }
            }
        }
        parked.sort_by_key(|(_, item)| state.ranges[item.id].0);
        state.deferred = parked.into();
        // A resumed run may already cover the first boundary (or more)
        // from restored ranges alone: replay the gate's decisions now,
        // before any worker claims work — this is what makes `--resume`
        // after an early stop re-issue nothing.
        consult_gate(&mut state, g, None);
        if let Some(e) = state.fatal.take() {
            return Err(e);
        }
    }

    let shared = Mutex::new(state);
    let work_ready = Condvar::new();
    let mut exec_stats: Vec<ExecutorStats> = (0..executors)
        .map(|eid| ExecutorStats { executor_id: eid, ..Default::default() })
        .collect();

    let gate = gate.as_ref();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(executors);
        for eid in 0..executors {
            let init = &init;
            let process = &process;
            let shared = &shared;
            let work_ready = &work_ready;
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || -> Result<ExecutorStats> {
                worker(
                    eid, df, batch_size, &cfg, progress, t0, shared, work_ready, sink, abort,
                    gate, init, process,
                )
            }));
        }
        for h in handles {
            let st = h.join().expect("executor thread panicked")?;
            exec_stats[st.executor_id] = st;
        }
        Ok(())
    })?;

    let mut state = shared.into_inner().unwrap();
    if let Some(e) = state.fatal.take() {
        return Err(e);
    }

    // Reassemble in row order and verify coverage: completed task ranges
    // must partition [0, settled_end) exactly (no duplicated/dropped
    // rows). An early-settled job returns the certified prefix only;
    // restored ranges overhanging the stop boundary are clipped.
    let settled_end = state.settled.unwrap_or(total_rows);
    let mut parts: Vec<(usize, usize, Vec<T>)> = Vec::with_capacity(state.ranges.len());
    for (id, result) in state.results.into_iter().enumerate() {
        let (start, end) = state.ranges[id];
        if start == end || start >= settled_end {
            continue;
        }
        let Some(rows) = result else {
            bail!("scheduler invariant violated: task {id} [{start}, {end}) never completed");
        };
        parts.push((start, end, rows));
    }
    parts.sort_by_key(|(start, _, _)| *start);
    let mut rows = Vec::with_capacity(settled_end);
    let mut cursor = 0;
    for (start, end, mut part) in parts {
        anyhow::ensure!(
            start == cursor && part.len() == end - start,
            "scheduler invariant violated: task range [{start}, {end}) does not tile the frame \
             at row {cursor}"
        );
        if end > settled_end {
            part.truncate(settled_end - start);
        }
        rows.extend(part);
        cursor = end.min(settled_end);
    }
    anyhow::ensure!(
        cursor == settled_end,
        "scheduler invariant violated: covered {cursor} of {settled_end} rows"
    );

    // Aggregate telemetry.
    let mut sched = SchedulerStats {
        tasks: state.ranges.iter().filter(|(s, e)| s != e).count(),
        steals: state.steals,
        speculative_launched: state.speculative_launched,
        speculative_wins: state.speculative_wins,
        splits: state.splits,
        retries: state.retries,
        restored_tasks: state.restored_tasks,
        restored_rows,
        rows_evaluated: settled_end,
        rows_saved: total_rows - settled_end,
        waves: state.waves,
        blacklisted_executors: (0..executors).filter(|&e| state.blacklisted[e]).collect(),
        wasted_rows: state
            .timeline
            .iter()
            .filter(|r| matches!(r.outcome, TaskOutcome::Lost | TaskOutcome::Abandoned))
            .map(|r| r.end - r.start)
            .sum(),
        ..Default::default()
    };
    let wins: Vec<f64> = state
        .timeline
        .iter()
        .filter(|r| r.outcome == TaskOutcome::Won)
        .map(|r| r.finished_at - r.started_at)
        .collect();
    if !wins.is_empty() {
        sched.longest_task_secs = wins.iter().cloned().fold(0.0, f64::max);
        sched.mean_task_secs = wins.iter().sum::<f64>() / wins.len() as f64;
        sched.skew_ratio = if sched.mean_task_secs > 0.0 {
            sched.longest_task_secs / sched.mean_task_secs
        } else {
            1.0
        };
    }

    Ok(SchedOutput { rows, executors: exec_stats, sched, timeline: state.timeline })
}

/// One executor thread: pull/steal/speculate tasks until the job is done.
#[allow(clippy::too_many_arguments)]
fn worker<T, S, FI, FP>(
    eid: usize,
    df: &DataFrame,
    batch_size: usize,
    cfg: &SchedulerConfig,
    progress: Option<&Progress>,
    t0: Instant,
    shared: &Mutex<SchedState<T>>,
    work_ready: &Condvar,
    sink: Option<&TaskSink<'_, T>>,
    abort: Option<&AtomicBool>,
    gate: Option<&WaveGate<'_, T>>,
    init: &FI,
    process: &FP,
) -> Result<ExecutorStats>
where
    T: Send,
    S: Send,
    FI: Fn(usize) -> Result<S> + Sync,
    FP: Fn(&mut S, &DataFrame, BatchSlice) -> Result<Vec<T>> + Sync,
{
    let _panic_guard = PanicGuard { shared, work_ready };
    let mut st = ExecutorStats { executor_id: eid, ..Default::default() };
    // Executor-local state is created once, before any task runs (the
    // paper's `_ENGINE_CACHE` semantics). An init failure is fatal for the
    // whole job, matching the static engine.
    let mut local = match init(eid) {
        Ok(s) => s,
        Err(e) => {
            // Shut the pool down, then surface the real error through this
            // worker's join handle.
            let mut state = shared.lock().unwrap();
            if state.fatal.is_none() {
                state.fatal = Some(anyhow::anyhow!("executor {eid} failed to initialize"));
            }
            work_ready.notify_all();
            drop(state);
            return Err(e.context(format!("initializing executor {eid}")));
        }
    };

    loop {
        // ------------------------------------------------ pick the next task
        let decision = {
            let mut state = shared.lock().unwrap();
            loop {
                check_abort(&mut state, abort, work_ready);
                if state.done() || state.blacklisted[eid] {
                    break Decision::Exit;
                }
                if let Some(d) = claim_task(&mut state, eid, cfg, t0) {
                    break d;
                }
                // Nothing claimable from here. If no attempt is running
                // anywhere AND every queue is drained, rows can never
                // complete — only reachable through a scheduler bug (with
                // stealing off, another executor's non-empty queue is not
                // claimable from here but is still live).
                if state.inflight.is_empty() && state.deques.iter().all(|d| d.is_empty()) {
                    state.fatal = Some(anyhow::anyhow!(
                        "scheduler stalled with {}/{} rows done",
                        state.rows_done,
                        state.total_rows
                    ));
                    work_ready.notify_all();
                    break Decision::Exit;
                }
                break Decision::Wait;
            }
        };

        let (item, attempt, start, end, started_secs) = match decision {
            Decision::Exit => break,
            Decision::Wait => {
                let mut state = shared.lock().unwrap();
                state.idle += 1;
                // Timed wait: bounded staleness for the idle counter the
                // split heuristic reads, and a safety net against a missed
                // wakeup ever deadlocking the pool.
                let (s, _timeout) = work_ready
                    .wait_timeout(state, std::time::Duration::from_millis(5))
                    .unwrap();
                state = s;
                state.idle -= 1;
                continue;
            }
            Decision::Run { item, attempt, start, end, started_secs } => {
                (item, attempt, start, end, started_secs)
            }
        };

        // ------------------------------------------------ run the task
        let mut out: Vec<T> = Vec::with_capacity(end - start);
        let mut cursor = start;
        let mut end = end;
        let mut failure: Option<anyhow::Error> = None;
        let mut abandoned = false;

        while cursor < end {
            let batch_end = (cursor + batch_size).min(end);
            let slice = BatchSlice { executor_id: eid, start: cursor, end: batch_end };
            // lint:allow(determinism): busy_secs is wall-clock telemetry by design
            let bt0 = Instant::now();
            // A panicking UDF is handled exactly like an erroring one: the
            // attempt fails and the task becomes eligible for retry /
            // blacklisting, instead of tearing the whole pool down. (The
            // executor-local state may be mid-mutation after an unwind;
            // it is only ever reused for full fresh batches, which every
            // UDF must already tolerate because retries replay batches.)
            let batch_result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                process(&mut local, df, slice)
            }))
            .unwrap_or_else(|payload| {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                Err(anyhow::anyhow!("UDF panicked: {msg}"))
            });
            match batch_result {
                Ok(batch_out) => {
                    let batch_secs = bt0.elapsed().as_secs_f64();
                    st.busy_secs += batch_secs;
                    if batch_out.len() != slice.len() {
                        failure = Some(anyhow::anyhow!(
                            "UDF returned {} rows for a {}-row batch",
                            batch_out.len(),
                            slice.len()
                        ));
                        break;
                    }
                    out.extend(batch_out);
                    st.rows_processed += slice.len();
                    st.batches += 1;
                    cursor = batch_end;

                    // Between batches: observe latency, abandon if a twin
                    // won or the run was aborted, and adaptively split
                    // oversized remainders.
                    let mut state = shared.lock().unwrap();
                    check_abort(&mut state, abort, work_ready);
                    state.ewma_batch_secs = if state.ewma_batch_secs == 0.0 {
                        batch_secs
                    } else {
                        0.8 * state.ewma_batch_secs + 0.2 * batch_secs
                    };
                    // Abandon only attempts with work still left: an
                    // attempt whose final batch just completed settles
                    // normally (Won/Lost) even under abort/fatal — its
                    // rows are fully paid for and must reach the
                    // checkpoint sink, not the floor.
                    let current_end = state.ranges[item.id].1;
                    if (state.completed[item.id] || state.fatal.is_some()) && cursor < current_end
                    {
                        abandoned = true;
                        break;
                    }
                    // The twin may have been launched after this attempt
                    // started; splits are sealed from then on, but the
                    // current range end may have shrunk earlier.
                    end = current_end;
                    if cursor >= end {
                        continue;
                    }
                    if cfg.adaptive_split
                        && cfg.work_stealing
                        && !item.speculative
                        && !state.speculated[item.id]
                        && state.idle > 0
                        && end - cursor > batch_size
                    {
                        // lint:allow(determinism): adaptive splitting reacts to real latency
                        let elapsed = (Instant::now() - t0).as_secs_f64() - started_secs;
                        let own_row_secs = elapsed / (cursor - start).max(1) as f64;
                        let est_remaining = (end - cursor) as f64 * own_row_secs;
                        let target = if cfg.target_task_secs > 0.0 {
                            cfg.target_task_secs
                        } else {
                            // Derived target: a task should not run much
                            // longer than a couple of observed batches.
                            (2.0 * state.ewma_batch_secs).max(1e-6)
                        };
                        if est_remaining > target {
                            let mid = cursor + (end - cursor).div_ceil(2);
                            state.ranges[item.id].1 = mid;
                            let child = state.new_task(mid, end);
                            state.splits += 1;
                            state.deques[eid].push_back(TaskItem { id: child, speculative: false });
                            end = mid;
                            work_ready.notify_all();
                        }
                    }
                }
                Err(e) => {
                    st.busy_secs += bt0.elapsed().as_secs_f64();
                    failure = Some(e);
                    break;
                }
            }
        }

        // ------------------------------------------------ settle the attempt
        // Encode rows for the checkpoint sink before taking the lock, so
        // encoding stays out of the critical section (a losing twin wastes
        // the encode, which is rare).
        let encoded: Option<Vec<String>> = match (&failure, abandoned, sink) {
            (None, false, Some(s)) => {
                Some(out.iter().map(|row| (s.encode)(row).to_string()).collect())
            }
            _ => None,
        };
        // lint:allow(determinism): TaskRecord timeline is wall-clock telemetry
        let finished_secs = (Instant::now() - t0).as_secs_f64();
        let mut state = shared.lock().unwrap();
        state.inflight.retain(|f| !(f.task_id == item.id && f.executor_id == eid));
        let (range_start, range_end) = state.ranges[item.id];

        let outcome = if let Some(err) = failure {
            settle_failure(&mut state, eid, item.id, cfg, err)
        } else if abandoned {
            TaskOutcome::Abandoned
        } else if state.completed[item.id] {
            TaskOutcome::Lost
        } else {
            debug_assert_eq!((cursor, out.len()), (range_end, range_end - range_start));
            state.completed[item.id] = true;
            state.completed_tasks += 1;
            state.results[item.id] = Some(std::mem::take(&mut out));
            state.rows_done += range_end - range_start;
            if let Some(p) = progress {
                p.add(range_end - range_start);
            }
            if item.speculative {
                state.speculative_wins += 1;
            }
            // The win may complete the current wave's prefix: consult
            // the gate while the lock is held (single flight — no other
            // attempt can extend coverage concurrently).
            if let Some(g) = gate {
                consult_gate(&mut state, g, Some(work_ready));
            }
            TaskOutcome::Won
        };
        state.timeline.push(TaskRecord {
            task_id: item.id,
            start: range_start,
            end: if outcome == TaskOutcome::Abandoned { cursor.max(range_start) } else { range_end },
            executor_id: eid,
            attempt,
            speculative: item.speculative,
            started_at: started_secs,
            finished_at: finished_secs,
            outcome,
        });
        work_ready.notify_all();
        drop(state);

        // Spill the winning attempt's rows outside the lock. Checkpointing
        // is best-effort durability: a failed write degrades a future
        // resume, not this run.
        if outcome == TaskOutcome::Won {
            if let (Some(s), Some(lines)) = (sink, encoded) {
                if let Err(e) =
                    s.stage.record_task(range_start, range_end, attempt, eid, &lines)
                {
                    eprintln!(
                        "warning: checkpoint write failed for rows \
                         [{range_start}, {range_end}): {e:#}"
                    );
                }
            }
        }
    }

    work_ready.notify_all();
    Ok(st)
}

/// Rows in `[0, b)` covered by completed task ranges (ranges are
/// disjoint, so this equals `b` exactly when the prefix is complete).
fn covered_prefix_rows<T>(state: &SchedState<T>, b: usize) -> usize {
    let mut covered = 0usize;
    for (id, &done) in state.completed.iter().enumerate() {
        if !done {
            continue;
        }
        let (s, e) = state.ranges[id];
        covered += e.min(b).saturating_sub(s.min(b));
    }
    covered
}

/// Under the lock (or pre-spawn, on the driver thread): while the
/// completed in-order prefix reaches the wave boundary, consult the gate
/// once per boundary — releasing the next wave on `Continue`, settling
/// the job early on `Stop`, failing it on a gate error. Loops because a
/// resumed run's restored ranges can cover several waves at once; the
/// decisions replay deterministically off the same prefixes.
fn consult_gate<T>(
    state: &mut SchedState<T>,
    gate: &WaveGate<'_, T>,
    work_ready: Option<&Condvar>,
) {
    loop {
        if state.settled.is_some() || state.fatal.is_some() {
            return;
        }
        let b = state.boundary;
        if b >= state.total_rows {
            // Final wave: the job finishes by exhausting its rows.
            return;
        }
        if covered_prefix_rows(state, b) < b {
            return;
        }
        let wave = state.waves;
        state.waves += 1;
        let decision = {
            // Assemble the exact in-order `[0, b)` prefix by reference
            // (restored ranges may overhang the boundary; clip them).
            let mut parts: Vec<(usize, &Vec<T>)> = Vec::new();
            for (id, result) in state.results.iter().enumerate() {
                let (s, e) = state.ranges[id];
                if s >= b || s == e || !state.completed[id] {
                    continue;
                }
                if let Some(rows) = result {
                    parts.push((s, rows));
                }
            }
            parts.sort_by_key(|(s, _)| *s);
            let mut prefix: Vec<&T> = Vec::with_capacity(b);
            for (s, rows) in parts {
                prefix.extend(rows.iter().take(b - s));
            }
            debug_assert_eq!(prefix.len(), b, "wave consult on an incomplete prefix");
            (gate.decide)(wave, &prefix)
        };
        match decision {
            Ok(WaveDecision::Continue) => {
                let nb = (b + gate.step.max(1)).min(state.total_rows);
                state.boundary = nb;
                while let Some(&(home, item)) = state.deferred.front() {
                    if state.ranges[item.id].0 >= nb {
                        break;
                    }
                    state.deferred.pop_front();
                    state.deques[home].push_back(item);
                }
                if let Some(w) = work_ready {
                    w.notify_all();
                }
                // Loop: restored coverage may already reach the new
                // boundary, in which case the next consult is due now.
            }
            Ok(WaveDecision::Stop) => {
                state.settled = Some(b);
                // Cancel every never-issued task: its range empties (so
                // reassembly skips it) and its rows become `rows_saved`.
                // In-flight attempts are left alone — they settle
                // normally and exit on `done()`.
                let deferred: Vec<(usize, TaskItem)> = state.deferred.drain(..).collect();
                for (_home, item) in deferred {
                    let s = state.ranges[item.id].0;
                    state.ranges[item.id] = (s, s);
                }
                // A complete prefix leaves no queued sub-boundary tasks;
                // anything still queued would be a scheduler bug.
                debug_assert!(state.deques.iter().all(|d| d.is_empty()));
                if let Some(w) = work_ready {
                    w.notify_all();
                }
                return;
            }
            Err(e) => {
                state.fatal = Some(e.context(format!("wave gate failed at wave {wave}")));
                if let Some(w) = work_ready {
                    w.notify_all();
                }
                return;
            }
        }
    }
}

/// Under the lock: fold an externally raised abort flag into the shared
/// fatal slot (once), so every worker winds down between batches. A flag
/// raised after the final row completed is ignored — a finished job is a
/// finished job (relevant for cost budgets tripped by the last batch).
fn check_abort<T>(state: &mut SchedState<T>, abort: Option<&AtomicBool>, work_ready: &Condvar) {
    if let Some(flag) = abort {
        if flag.load(Ordering::Relaxed)
            && state.fatal.is_none()
            && state.rows_done < state.total_rows
        {
            state.fatal = Some(anyhow::anyhow!(
                "run aborted with {}/{} rows complete",
                state.rows_done,
                state.total_rows
            ));
            work_ready.notify_all();
        }
    }
}

/// Under the lock: find something for `eid` to do. Returns `None` when
/// there is nothing to run right now (caller waits or exits).
fn claim_task<T>(
    state: &mut SchedState<T>,
    eid: usize,
    cfg: &SchedulerConfig,
    t0: Instant,
) -> Option<Decision> {
    // 1. Own queue, front first (ascending row order).
    let mut claimed: Option<TaskItem> = state.deques[eid].pop_front();

    // 2. Steal from the back of the longest other queue.
    if claimed.is_none() && cfg.work_stealing {
        let victim = (0..state.deques.len())
            .filter(|&v| v != eid && !state.deques[v].is_empty())
            .max_by_key(|&v| state.deques[v].len());
        if let Some(v) = victim {
            claimed = state.deques[v].pop_back();
            state.steals += 1;
        }
    }

    // 3. Speculate: duplicate the longest-running unduplicated straggler.
    // Restored tasks are excluded from the quantile: the trigger reasons
    // about *this* run's progress, not carried-over checkpoint work.
    if claimed.is_none() && cfg.speculation {
        let total = state.ranges.len() - state.restored_tasks;
        let fresh_done = state.completed_tasks - state.restored_tasks;
        let threshold = (cfg.speculation_quantile * total as f64).ceil() as usize;
        if total > 0 && fresh_done >= threshold && fresh_done < total {
            let straggler = state
                .inflight
                .iter()
                .filter(|f| {
                    !f.speculative
                        && !state.completed[f.task_id]
                        && !state.speculated[f.task_id]
                })
                .min_by(|a, b| a.started_secs.total_cmp(&b.started_secs))
                .copied();
            if let Some(f) = straggler {
                state.speculated[f.task_id] = true;
                state.speculative_launched += 1;
                claimed = Some(TaskItem { id: f.task_id, speculative: true });
            }
        }
    }

    let item = claimed?;
    // Queued tasks are never completed (only in-flight attempts complete),
    // so every claim is runnable.
    debug_assert!(item.speculative || !state.completed[item.id]);
    let (start, end) = state.ranges[item.id];
    // lint:allow(determinism): TaskRecord timeline is wall-clock telemetry
    let started_secs = (Instant::now() - t0).as_secs_f64();
    state.inflight.push(InFlight {
        task_id: item.id,
        executor_id: eid,
        speculative: item.speculative,
        started_secs,
    });
    let attempt = state.attempts_failed[item.id] + 1;
    Some(Decision::Run { item, attempt, start, end, started_secs })
}

/// Under the lock: record a failed attempt, schedule a retry or declare the
/// job dead, and blacklist repeat-offender executors.
fn settle_failure<T>(
    state: &mut SchedState<T>,
    eid: usize,
    task_id: usize,
    cfg: &SchedulerConfig,
    err: anyhow::Error,
) -> TaskOutcome {
    state.failures_per_executor[eid] += 1;

    // Blacklist before re-enqueueing so the retry never lands back on the
    // failing executor's queue.
    if state.failures_per_executor[eid] >= cfg.blacklist_after && !state.blacklisted[eid] {
        state.blacklisted[eid] = true;
        let orphans: Vec<TaskItem> = state.deques[eid].drain(..).collect();
        let heirs: Vec<usize> =
            (0..state.deques.len()).filter(|&e| !state.blacklisted[e]).collect();
        if heirs.is_empty() {
            if state.fatal.is_none() {
                state.fatal = Some(err.context(format!(
                    "all {} executors blacklisted after repeated task failures",
                    state.deques.len()
                )));
            }
            return TaskOutcome::Failed;
        }
        for (i, item) in orphans.into_iter().enumerate() {
            state.deques[heirs[i % heirs.len()]].push_back(item);
        }
    }

    if state.completed[task_id] {
        // A twin already won; the failure costs nothing.
        return TaskOutcome::Failed;
    }

    state.attempts_failed[task_id] += 1;
    let still_running = state
        .inflight
        .iter()
        .any(|f| f.task_id == task_id);
    if still_running {
        // A twin attempt is still in flight; it is the retry.
        return TaskOutcome::Failed;
    }

    if state.attempts_failed[task_id] >= cfg.max_task_attempts {
        if state.fatal.is_none() {
            let (start, end) = state.ranges[task_id];
            state.fatal = Some(err.context(format!(
                "task {task_id} [rows {start}..{end}) failed after {} attempts",
                state.attempts_failed[task_id]
            )));
        }
        return TaskOutcome::Failed;
    }

    state.retries += 1;
    // Retry on the next non-blacklisted executor after the failing one.
    let n = state.deques.len();
    let target = (1..=n)
        .map(|d| (eid + d) % n)
        .find(|&e| !state.blacklisted[e])
        .unwrap_or(eid);
    state.deques[target].push_back(TaskItem { id: task_id, speculative: false });
    TaskOutcome::Failed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Value;
    use crate::engine::run_partitioned;
    use crate::util::proptest::{check, ensure};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn frame(n: usize) -> DataFrame {
        DataFrame::from_columns(vec![(
            "x",
            (0..n as i64).map(Value::Int).collect::<Vec<_>>(),
        )])
        .unwrap()
    }

    fn identity_udf(
    ) -> impl Fn(&mut (), &DataFrame, BatchSlice) -> Result<Vec<f64>> + Sync {
        |_s, df, slice: BatchSlice| {
            Ok(slice
                .indices()
                .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                .collect())
        }
    }

    #[test]
    fn output_matches_static_engine_property() {
        check("scheduled output == static output", 40, |rng| {
            let n = rng.below(300);
            let executors = 1 + rng.below(8);
            let batch = 1 + rng.below(16);
            let cfg = SchedulerConfig {
                tasks_per_executor: 1 + rng.below(6),
                speculation_quantile: 0.5 + 0.5 * rng.f64(),
                ..Default::default()
            };
            let df = frame(n);
            let out =
                run_scheduled(&df, executors, batch, &cfg, None, |_| Ok(()), identity_udf())
                    .unwrap();
            let expected =
                run_partitioned(&df, executors, batch, |_| Ok(()), identity_udf()).unwrap();
            ensure(out.rows == expected.rows, "row-for-row identity")?;
            ensure(out.rows.len() == n, "length")?;
            let processed: usize = out.executors.iter().map(|e| e.rows_processed).sum();
            ensure(processed >= n, "telemetry covers all rows")?;
            Ok(())
        });
    }

    #[test]
    fn retry_and_speculation_never_duplicate_or_drop_rows() {
        check("faulty UDF still yields exact rows", 25, |rng| {
            let n = 1 + rng.below(250);
            let executors = 1 + rng.below(6);
            let batch = 1 + rng.below(10);
            let seed = rng.next_u64();
            let cfg = SchedulerConfig {
                tasks_per_executor: 1 + rng.below(5),
                max_task_attempts: 12,
                blacklist_after: usize::MAX,
                ..Default::default()
            };
            let df = frame(n);
            let out = run_scheduled(
                &df,
                executors,
                batch,
                &cfg,
                None,
                |eid| Ok(crate::util::rng::Rng::with_stream(seed, eid as u64)),
                |rng, df, slice: BatchSlice| {
                    if rng.chance(0.25) {
                        anyhow::bail!("injected transient failure");
                    }
                    Ok(slice
                        .indices()
                        .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                        .collect())
                },
            )
            .unwrap();
            ensure(out.rows.len() == n, "length")?;
            for (i, v) in out.rows.iter().enumerate() {
                ensure(*v == i as f64, format!("row {i} = {v}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn work_stealing_rebalances_skewed_partitions() {
        // Executor 0 is 30x slower per row; with stealing, the fast
        // executors take most of its queued tasks.
        let n = 120;
        let df = frame(n);
        let cfg = SchedulerConfig {
            tasks_per_executor: 6,
            speculation: false,
            adaptive_split: false,
            ..Default::default()
        };
        let out = run_scheduled(
            &df,
            4,
            5,
            &cfg,
            None,
            |eid| Ok(eid),
            |eid, df, slice: BatchSlice| {
                if *eid == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(3 * slice.len() as u64));
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
        assert!(out.sched.steals > 0, "expected steals, got {:?}", out.sched);
        let slow_rows = out.executors[0].rows_processed;
        assert!(
            slow_rows < n / 2,
            "slow executor should shed load, processed {slow_rows}/{n}"
        );
    }

    #[test]
    fn speculation_duplicates_straggler_and_first_completion_wins() {
        // Executor 0 crawls (40ms per row); everyone else is instant. Once
        // the fast executors finish, the straggler's task is duplicated and
        // the duplicate wins.
        let n = 64;
        let df = frame(n);
        let cfg = SchedulerConfig {
            tasks_per_executor: 2,
            speculation: true,
            speculation_quantile: 0.5,
            adaptive_split: false,
            ..Default::default()
        };
        let out = run_scheduled(
            &df,
            4,
            4,
            &cfg,
            None,
            |eid| Ok(eid),
            |eid, df, slice: BatchSlice| {
                if *eid == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(40 * slice.len() as u64));
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
        assert!(
            out.sched.speculative_launched >= 1,
            "expected speculation: {:?}",
            out.sched
        );
        assert!(
            out.sched.speculative_wins >= 1,
            "duplicate should beat a 40ms/row straggler: {:?}",
            out.sched
        );
        assert!(out
            .timeline
            .iter()
            .any(|r| r.speculative && r.outcome == TaskOutcome::Won));
    }

    #[test]
    fn failing_executor_is_blacklisted_and_job_completes() {
        let n = 90;
        let df = frame(n);
        let cfg = SchedulerConfig {
            tasks_per_executor: 3,
            speculation: false,
            adaptive_split: false,
            max_task_attempts: 4,
            blacklist_after: 2,
            ..Default::default()
        };
        let out = run_scheduled(
            &df,
            3,
            5,
            &cfg,
            None,
            |eid| Ok(eid),
            |eid, df, slice: BatchSlice| {
                if *eid == 1 {
                    anyhow::bail!("executor 1 always fails");
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(out.sched.blacklisted_executors, vec![1]);
        assert!(out.sched.retries >= 1, "{:?}", out.sched);
        assert!(out
            .timeline
            .iter()
            .any(|r| r.executor_id == 1 && r.outcome == TaskOutcome::Failed));
    }

    #[test]
    fn udf_panic_propagates_without_hanging() {
        // A deterministically panicking UDF no longer tears the pool down:
        // each panic is a retryable task failure, and once attempts are
        // exhausted the job returns an error (without hanging) whose chain
        // names the panic.
        let df = frame(40);
        let cfg = SchedulerConfig::default();
        let err = run_scheduled(
            &df,
            3,
            5,
            &cfg,
            None,
            |_| Ok(()),
            |_, _df, slice: BatchSlice| {
                if slice.start >= 20 {
                    panic!("boom in udf");
                }
                Ok(vec![0u8; slice.len()])
            },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
    }

    #[test]
    fn transient_udf_panic_is_retried_and_job_completes() {
        // One panic on the first touch of row 10's batch; the retry (on a
        // different executor) succeeds and the output is still exact.
        let n = 60;
        let df = frame(n);
        let fired = std::sync::atomic::AtomicBool::new(false);
        let cfg = SchedulerConfig {
            tasks_per_executor: 3,
            speculation: false,
            adaptive_split: false,
            max_task_attempts: 3,
            blacklist_after: usize::MAX,
            ..Default::default()
        };
        let out = run_scheduled(
            &df,
            3,
            5,
            &cfg,
            None,
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                if slice.start <= 10 && slice.end > 10 && !fired.swap(true, Ordering::SeqCst) {
                    panic!("transient panic at row 10");
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
        assert!(out.sched.retries >= 1, "{:?}", out.sched);
        assert!(out
            .timeline
            .iter()
            .any(|r| r.outcome == TaskOutcome::Failed));
    }

    #[test]
    fn init_panic_still_shuts_pool_down() {
        // Executor-local init panics are not retryable: the pool shuts
        // down and the panic surfaces through the join, as before.
        let df = frame(30);
        let cfg = SchedulerConfig::default();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _ = run_scheduled(
                &df,
                3,
                5,
                &cfg,
                None,
                |eid| {
                    if eid == 1 {
                        panic!("init panic on executor 1");
                    }
                    Ok(())
                },
                |_, _df, slice: BatchSlice| Ok(vec![0u8; slice.len()]),
            );
        }));
        assert!(r.is_err(), "init panic must propagate");
    }

    #[test]
    fn abort_flag_stops_job_midflight() {
        let n = 200;
        let df = frame(n);
        let abort = AtomicBool::new(false);
        let seen = AtomicUsize::new(0);
        let cfg = SchedulerConfig {
            tasks_per_executor: 8,
            speculation: false,
            adaptive_split: false,
            ..Default::default()
        };
        let err = run_scheduled_ext(
            &df,
            4,
            5,
            &cfg,
            None,
            None,
            Some(&abort),
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                if seen.fetch_add(slice.len(), Ordering::SeqCst) >= n / 2 {
                    abort.store(true, Ordering::SeqCst);
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("aborted"), "{err:#}");
    }

    #[test]
    fn restored_ranges_are_never_re_executed() {
        // Rows [0, 50) come from a checkpoint; only [50, 120) may run.
        let n = 120;
        let df = frame(n);
        let cfg = SchedulerConfig::default();
        let restored: Vec<(usize, usize, Vec<f64>)> =
            vec![(0, 50, (0..50).map(|i| i as f64).collect())];
        let touched = Mutex::new(vec![0usize; n]);
        let out = run_scheduled_ext(
            &df,
            4,
            7,
            &cfg,
            None,
            Some(TaskCheckpoint { restored, sink: None }),
            None,
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                {
                    let mut touched = touched.lock().unwrap();
                    for i in slice.indices() {
                        touched[i] += 1;
                    }
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(out.sched.restored_tasks, 1);
        assert_eq!(out.sched.restored_rows, 50);
        let touched = touched.into_inner().unwrap();
        assert!(
            touched[..50].iter().all(|&c| c == 0),
            "restored rows must not re-execute"
        );
        assert!(touched[50..].iter().all(|&c| c >= 1), "gap rows must run");
    }

    #[test]
    fn invalid_restored_ranges_are_rejected() {
        let df = frame(20);
        let cfg = SchedulerConfig::default();
        // Overlapping ranges.
        let bad: Vec<(usize, usize, Vec<f64>)> = vec![
            (0, 10, (0..10).map(|i| i as f64).collect()),
            (5, 15, (5..15).map(|i| i as f64).collect()),
        ];
        let r = run_scheduled_ext(
            &df,
            2,
            5,
            &cfg,
            None,
            Some(TaskCheckpoint { restored: bad, sink: None }),
            None,
            |_| Ok(()),
            identity_udf(),
        );
        assert!(r.is_err());
        // Row-count mismatch.
        let bad: Vec<(usize, usize, Vec<f64>)> = vec![(0, 10, vec![1.0, 2.0])];
        let r = run_scheduled_ext(
            &df,
            2,
            5,
            &cfg,
            None,
            Some(TaskCheckpoint { restored: bad, sink: None }),
            None,
            |_| Ok(()),
            identity_udf(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn task_exhausting_attempts_fails_the_job() {
        let df = frame(40);
        let cfg = SchedulerConfig {
            tasks_per_executor: 2,
            max_task_attempts: 3,
            blacklist_after: usize::MAX,
            ..Default::default()
        };
        let err = run_scheduled(
            &df,
            2,
            10,
            &cfg,
            None,
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                if slice.start >= 20 {
                    anyhow::bail!("rows past 20 are poison");
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("failed after 3 attempts"), "{msg}");
    }

    #[test]
    fn adaptive_split_carves_up_oversized_tasks() {
        // Two tasks; task 0's rows are slow. The idle second executor
        // should receive split-off children of the big slow task.
        let n = 80;
        let df = frame(n);
        let cfg = SchedulerConfig {
            tasks_per_executor: 1,
            speculation: false,
            adaptive_split: true,
            ..Default::default()
        };
        let out = run_scheduled(
            &df,
            2,
            4,
            &cfg,
            None,
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                if slice.start < n / 2 {
                    std::thread::sleep(std::time::Duration::from_millis(
                        4 * slice.len() as u64,
                    ));
                }
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..n).map(|i| i as f64).collect::<Vec<_>>());
        assert!(out.sched.splits >= 1, "expected adaptive splits: {:?}", out.sched);
    }

    #[test]
    fn progress_counter_reaches_completion() {
        let df = frame(130);
        let progress = Progress::new(130);
        let cfg = SchedulerConfig::default();
        run_scheduled(&df, 4, 10, &cfg, Some(&progress), |_| Ok(()), identity_udf()).unwrap();
        assert!((progress.fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn init_runs_once_per_executor_even_with_stealing() {
        let inits = AtomicUsize::new(0);
        let df = frame(200);
        let cfg = SchedulerConfig::default();
        run_scheduled(
            &df,
            6,
            7,
            &cfg,
            None,
            |eid| {
                inits.fetch_add(1, Ordering::SeqCst);
                Ok(eid)
            },
            |_, df, slice: BatchSlice| {
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect::<Vec<_>>())
            },
        )
        .unwrap();
        assert_eq!(inits.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn init_error_fails_the_job() {
        let df = frame(30);
        let cfg = SchedulerConfig::default();
        let r = run_scheduled(
            &df,
            3,
            5,
            &cfg,
            None,
            |eid| {
                if eid == 2 {
                    anyhow::bail!("no credentials on executor 2");
                }
                Ok(())
            },
            identity_udf(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn empty_frame_and_tiny_frames() {
        let cfg = SchedulerConfig::default();
        let out =
            run_scheduled(&frame(0), 4, 10, &cfg, None, |_| Ok(()), identity_udf()).unwrap();
        assert!(out.rows.is_empty());
        assert_eq!(out.sched.tasks, 0);

        let out =
            run_scheduled(&frame(3), 8, 10, &cfg, None, |_| Ok(()), identity_udf()).unwrap();
        assert_eq!(out.rows, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn config_json_round_trip_and_validation() {
        let cfg = SchedulerConfig {
            tasks_per_executor: 7,
            work_stealing: false,
            speculation: true,
            speculation_quantile: 0.9,
            max_task_attempts: 5,
            blacklist_after: 2,
            adaptive_split: false,
            target_task_secs: 1.5,
        };
        let restored = SchedulerConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, restored);

        // The legacy preset's blacklist_after = usize::MAX ("never") must
        // survive the round-trip via the sentinel.
        let legacy = SchedulerConfig::legacy();
        assert_eq!(SchedulerConfig::from_json(&legacy.to_json()).unwrap(), legacy);

        let mut bad = SchedulerConfig::default();
        bad.tasks_per_executor = 0;
        assert!(bad.validate().is_err());
        let mut bad = SchedulerConfig::default();
        bad.speculation_quantile = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = SchedulerConfig::default();
        bad.max_task_attempts = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn wave_gate_stops_early_with_exact_prefix_and_accounting() {
        let n = 200;
        let df = frame(n);
        let cfg = SchedulerConfig::default();
        let max_start = AtomicUsize::new(0);
        let decide = |_wave: usize, prefix: &[&f64]| -> Result<WaveDecision> {
            // Certify once 100 rows are in; the prefix must be exact and
            // in order at every consult.
            for (i, v) in prefix.iter().enumerate() {
                assert_eq!(**v, i as f64, "prefix out of order at {i}");
            }
            Ok(if prefix.len() >= 100 { WaveDecision::Stop } else { WaveDecision::Continue })
        };
        let out = run_scheduled_wave(
            &df,
            4,
            10,
            &cfg,
            None,
            None,
            None,
            Some(WaveGate { first: 50, step: 50, decide: &decide }),
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                max_start.fetch_max(slice.start, Ordering::SeqCst);
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect::<Vec<_>>())
            },
        )
        .unwrap();
        assert_eq!(out.rows, (0..100).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(out.sched.rows_evaluated, 100);
        assert_eq!(out.sched.rows_saved, 100);
        assert_eq!(out.sched.rows_evaluated + out.sched.rows_saved, n);
        assert_eq!(out.sched.waves, 2);
        // Rows past the stop boundary were never issued, let alone run.
        assert!(max_start.load(Ordering::SeqCst) < 100, "{}", max_start.load(Ordering::SeqCst));
    }

    #[test]
    fn wave_gate_that_never_stops_matches_ungated_run() {
        let n = 130;
        let df = frame(n);
        let cfg = SchedulerConfig::default();
        let looks = Mutex::new(Vec::new());
        let decide = |wave: usize, prefix: &[&f64]| -> Result<WaveDecision> {
            looks.lock().unwrap().push((wave, prefix.len()));
            Ok(WaveDecision::Continue)
        };
        let out = run_scheduled_wave(
            &df,
            3,
            10,
            &cfg,
            None,
            None,
            None,
            Some(WaveGate { first: 40, step: 40, decide: &decide }),
            |_| Ok(()),
            identity_udf(),
        )
        .unwrap();
        let plain = run_scheduled(&df, 3, 10, &cfg, None, |_| Ok(()), identity_udf()).unwrap();
        assert_eq!(out.rows, plain.rows);
        assert_eq!(out.sched.rows_evaluated, n);
        assert_eq!(out.sched.rows_saved, 0);
        // Boundaries are pure config: consults at exactly 40, 80, 120
        // (the final partial wave completes the job with no consult).
        assert_eq!(*looks.lock().unwrap(), vec![(0, 40), (1, 80), (2, 120)]);
        assert_eq!(out.sched.waves, 3);
        // Ungated jobs report classic accounting.
        assert_eq!(plain.sched.rows_evaluated, n);
        assert_eq!(plain.sched.rows_saved, 0);
        assert_eq!(plain.sched.waves, 0);
    }

    #[test]
    fn wave_gate_error_fails_the_job() {
        let df = frame(60);
        let cfg = SchedulerConfig::default();
        let decide = |_wave: usize, _prefix: &[&f64]| -> Result<WaveDecision> {
            anyhow::bail!("stopping rule exploded")
        };
        let err = run_scheduled_wave(
            &df,
            2,
            10,
            &cfg,
            None,
            None,
            None,
            Some(WaveGate { first: 20, step: 20, decide: &decide }),
            |_| Ok(()),
            identity_udf(),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("wave gate failed at wave 0"), "{msg}");
        assert!(msg.contains("stopping rule exploded"), "{msg}");
    }

    #[test]
    fn wave_gate_replays_decisions_over_restored_prefix_without_running() {
        // A resumed run whose restored ranges already cover the stop
        // boundary must settle before issuing ANY work — this is what
        // makes `--resume` after an early stop re-inference-free.
        let n = 200;
        let df = frame(n);
        let cfg = SchedulerConfig::default();
        let ran = AtomicUsize::new(0);
        let decide = |_wave: usize, prefix: &[&f64]| -> Result<WaveDecision> {
            Ok(if prefix.len() >= 100 { WaveDecision::Stop } else { WaveDecision::Continue })
        };
        // Restored coverage overhangs the stop boundary (rows 0..120):
        // the output must clip to the certified 100-row prefix.
        let restored = vec![(0usize, 120usize, (0..120).map(|i| i as f64).collect::<Vec<_>>())];
        let out = run_scheduled_wave(
            &df,
            4,
            10,
            &cfg,
            None,
            Some(TaskCheckpoint { restored, sink: None }),
            None,
            Some(WaveGate { first: 50, step: 50, decide: &decide }),
            |_| Ok(()),
            |_, df, slice: BatchSlice| {
                ran.fetch_add(slice.len(), Ordering::SeqCst);
                Ok(slice
                    .indices()
                    .map(|i| df.row(i).get("x").unwrap().as_f64().unwrap())
                    .collect::<Vec<_>>())
            },
        )
        .unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 0, "restored prefix must replay, not re-run");
        assert_eq!(out.rows, (0..100).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(out.sched.rows_evaluated, 100);
        assert_eq!(out.sched.rows_saved, 100);
        assert_eq!(out.sched.waves, 2);
    }

    #[test]
    fn wave_stats_merge_accumulates_saved_rows() {
        let mut a = SchedulerStats {
            rows_evaluated: 100,
            rows_saved: 50,
            waves: 2,
            ..Default::default()
        };
        let b =
            SchedulerStats { rows_evaluated: 70, rows_saved: 0, waves: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!((a.rows_evaluated, a.rows_saved, a.waves), (170, 50, 3));
        let j = a.to_json();
        assert_eq!(j.get("rows_saved").unwrap().as_f64().unwrap(), 50.0);
        assert_eq!(j.get("waves").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn legacy_preset_matches_static_partition_layout() {
        // With the legacy preset each executor processes exactly its own
        // contiguous partition — the static engine contract.
        let df = frame(60);
        let out = run_scheduled(
            &df,
            4,
            5,
            &SchedulerConfig::legacy(),
            None,
            |eid| Ok(eid),
            |eid, _df, slice: BatchSlice| Ok(vec![*eid; slice.len()]),
        )
        .unwrap();
        for eid in 0..4 {
            let rows: Vec<usize> = out.rows.iter().copied().filter(|&e| e == eid).collect();
            assert_eq!(rows.len(), 15);
        }
        assert_eq!(out.sched.steals, 0);
        assert_eq!(out.sched.speculative_launched, 0);
        for st in &out.executors {
            assert_eq!(st.rows_processed, 15);
            assert_eq!(st.batches, 3);
        }
    }
}
