//! Executor backends: where scheduled tasks physically run.
//!
//! [`run_scheduled`](crate::sched::run_scheduled) executes closures on
//! scoped threads inside the driver process — fast, but a single executor
//! *crash* (abort, OOM kill, segfault — not a catchable panic) takes the
//! whole run down, and multi-host scale-out is structurally impossible.
//! This module inverts the scheduler into a driver loop over an
//! [`ExecutorBackend`]:
//!
//! - the driver owns the scheduling state (queues, stealing, speculation,
//!   retries, blacklisting) and pushes [`TaskSpec`]s to executors;
//! - executors run a serializable [`TaskPlan`](super::plan::TaskPlan)
//!   and report [`TaskResultMsg`]s;
//! - [`ThreadBackend`] runs executors on in-process threads (one
//!   plan-built executor per thread) — the in-process reference
//!   implementation of the protocol;
//! - [`ProcessBackend`] spawns `slleval worker` child processes and
//!   speaks a length-prefixed JSON protocol over stdin/stdout pipes.
//!   Child death (pipe EOF / wait status) becomes an
//!   [`ExecutorEvent::Died`], which the driver folds into the existing
//!   retry + blacklist machinery: a `kill -9`'d executor costs only its
//!   in-flight task, and everything already spilled to the run
//!   checkpoint survives for `--resume`.
//!
//! Wire protocol (each frame is a 4-byte big-endian length + UTF-8 JSON):
//!
//! ```text
//! driver -> worker   {"type":"hello","executor_id":E,"batch_size":B,"plan":{...}}
//!                    {"type":"task","task_id":T,"start":S,"end":E,"attempt":A,"speculative":false}
//!                    {"type":"plan","executor_id":E,"batch_size":B,"plan":{...}}   (re-arm a persistent worker)
//!                    {"type":"shutdown"}
//! worker -> driver   {"type":"ready"} | {"type":"init_error","error":"..."}
//!                    {"type":"result", ...TaskResultMsg}
//!                    {"type":"task_error","task_id":T,"error":"..."}
//!                    {"type":"heartbeat"}                         (serve-worker liveness)
//!                    {"type":"spill","start":S,"end":E,"attempt":A,"rows":[...]}  (remote spill upload)
//! ```
//!
//! The same frames ride TCP sockets for [`RemoteBackend`]
//! (`super::remote`); unknown frame types are ignored on both sides for
//! forward compatibility.
//!
//! The driver loop does not support adaptive task splitting (a worker
//! reports nothing mid-task), and aborts (cost budget, Ctrl-C) take
//! effect at task rather than batch granularity. Everything else —
//! stealing, speculation, retry, blacklisting, checkpoint restore/spill,
//! row-exact reassembly, and wave gating (adaptive early stopping via
//! [`run_plan_wave`], entirely driver-side: workers never see the gate,
//! so no new protocol frames) — matches [`run_scheduled`]'s semantics.
//!
//! [`run_scheduled`]: crate::sched::run_scheduled

use super::plan::TaskPlan;
use super::{SchedulerConfig, SchedulerStats, TaskOutcome, TaskRecord, WaveDecision, WaveGate};
use crate::engine::{ExecutorStats, Progress};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which backend executes scheduler tasks (`executor.backend` in the
/// task JSON, `--backend` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// In-process scoped threads (the default; bit-identical to the
    /// pre-backend scheduler).
    #[default]
    Thread,
    /// One `slleval worker` OS process per executor (crash isolation).
    Process,
    /// Executors on remote `slleval serve-worker` hosts over TCP
    /// (requires `executor.hosts` / `--hosts`).
    Remote,
}

impl BackendKind {
    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Thread => "thread",
            BackendKind::Process => "process",
            BackendKind::Remote => "remote",
        }
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "thread" => BackendKind::Thread,
            "process" => BackendKind::Process,
            "remote" => BackendKind::Remote,
            other => bail!("unknown executor backend '{other}' (thread | process | remote)"),
        })
    }
}

/// One task assignment pushed to an executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskSpec {
    pub task_id: usize,
    pub start: usize,
    pub end: usize,
    /// 1-based attempt number.
    pub attempt: usize,
    pub speculative: bool,
}

impl TaskSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("type", Json::str("task")),
            ("task_id", Json::num(self.task_id as f64)),
            ("start", Json::num(self.start as f64)),
            ("end", Json::num(self.end as f64)),
            ("attempt", Json::num(self.attempt as f64)),
            ("speculative", Json::Bool(self.speculative)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TaskSpec> {
        Ok(TaskSpec {
            task_id: v.get("task_id")?.as_usize()?,
            start: v.get("start")?.as_usize()?,
            end: v.get("end")?.as_usize()?,
            attempt: v.usize_or("attempt", 1),
            speculative: v.bool_or("speculative", false),
        })
    }
}

/// One completed task attempt: the rows plus the per-task accounting the
/// in-process scheduler used to accumulate through shared memory.
#[derive(Debug, Clone)]
pub struct TaskResultMsg {
    pub task_id: usize,
    pub start: usize,
    pub end: usize,
    pub attempt: usize,
    pub speculative: bool,
    /// One JSON value per row in `[start, end)` (kind-specific codec).
    pub rows: Vec<Json>,
    pub rows_processed: usize,
    pub batches: usize,
    pub busy_secs: f64,
    pub peak_in_flight: usize,
    /// Provider spend of this attempt (every API call, retries included).
    pub api_calls: u64,
    pub retries: u64,
    pub cost_usd: f64,
}

impl TaskResultMsg {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("type", Json::str("result")),
            ("task_id", Json::num(self.task_id as f64)),
            ("start", Json::num(self.start as f64)),
            ("end", Json::num(self.end as f64)),
            ("attempt", Json::num(self.attempt as f64)),
            ("speculative", Json::Bool(self.speculative)),
            ("rows", Json::arr(self.rows.clone())),
            ("rows_processed", Json::num(self.rows_processed as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("busy_secs", Json::num(self.busy_secs)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            ("api_calls", Json::num(self.api_calls as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("cost_usd", Json::num(self.cost_usd)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TaskResultMsg> {
        Ok(TaskResultMsg {
            task_id: v.get("task_id")?.as_usize()?,
            start: v.get("start")?.as_usize()?,
            end: v.get("end")?.as_usize()?,
            attempt: v.usize_or("attempt", 1),
            speculative: v.bool_or("speculative", false),
            rows: v.get("rows")?.as_arr()?.to_vec(),
            rows_processed: v.usize_or("rows_processed", 0),
            batches: v.usize_or("batches", 0),
            busy_secs: v.f64_or("busy_secs", 0.0),
            peak_in_flight: v.usize_or("peak_in_flight", 0),
            api_calls: v.f64_or("api_calls", 0.0) as u64,
            retries: v.f64_or("retries", 0.0) as u64,
            cost_usd: v.f64_or("cost_usd", 0.0),
        })
    }
}

/// What a backend reports back to the driver loop.
#[derive(Debug)]
pub enum ExecutorEvent {
    /// Executor-local state built; the executor accepts tasks.
    Ready { executor_id: usize },
    /// Executor-local construction failed (fatal, like the thread
    /// scheduler's init-failure semantics).
    InitError { executor_id: usize, error: String },
    TaskDone { executor_id: usize, result: TaskResultMsg },
    /// The task's UDF-equivalent failed or panicked (retryable).
    TaskFailed { executor_id: usize, task_id: usize, error: String },
    /// The executor itself is gone (process exit / pipe EOF / fault
    /// injection). Its in-flight task is lost and it takes no more work.
    Died { executor_id: usize, detail: String },
}

/// The execution substrate the driver schedules onto. One backend
/// instance drives one job; executors are spawned up front, fed one task
/// at a time, and shut down when the job settles.
pub trait ExecutorBackend {
    /// Human tag for logs and stats (`"thread"` / `"process"`).
    fn name(&self) -> &'static str;
    /// Start executor `executor_id` (builds executor-local state
    /// asynchronously; completion is signalled by [`ExecutorEvent::Ready`]).
    fn spawn_executor(&mut self, executor_id: usize) -> Result<()>;
    /// Push one task to an executor. An error means the executor is
    /// unreachable — the driver settles it as dead.
    fn submit(&mut self, executor_id: usize, spec: &TaskSpec) -> Result<()>;
    /// Wait up to `timeout` for the next event.
    fn poll(&mut self, timeout: Duration) -> Option<ExecutorEvent>;
    /// Liveness probe (process: `try_wait`; thread: join-handle state).
    fn alive(&self, executor_id: usize) -> bool;
    /// Stop every executor (best-effort, idempotent).
    fn shutdown(&mut self);
    /// Which physical host an executor runs on, when the backend places
    /// multiple executors per failure domain (remote: index into the
    /// host list). `None` means each executor is its own failure domain,
    /// and executor death stays executor-scoped.
    fn host_of(&self, _executor_id: usize) -> Option<usize> {
        None
    }
}

// --------------------------------------------------------------- framing

// The 4-byte-BE + JSON frame codec lives in [`super::wire`], shared by
// every transport (pipes here, TCP in [`super::remote`], and both worker
// serve modes). Re-exported so existing `backend::read_frame` imports
// keep working.
pub use super::wire::{read_frame, write_frame, write_frame_bytes};

// --------------------------------------------------------- thread backend

/// Executes one plan-built task range; implemented by
/// `coordinator::plan_exec::PlanExecutor`. The factory indirection keeps
/// `sched` free of coordinator types while letting [`ThreadBackend`] run
/// the exact same task-side code a worker process runs.
pub trait PlanTaskRunner {
    fn run(&mut self, spec: &TaskSpec, batch_size: usize) -> Result<TaskResultMsg>;
    /// Flush buffered side effects (cache writes) at shutdown.
    fn finish(&mut self) {}
}

/// Builds one executor's [`PlanTaskRunner`] inside its executor thread.
pub type RunnerFactory = Arc<dyn Fn(usize) -> Result<Box<dyn PlanTaskRunner>> + Send + Sync>;

enum ThreadCmd {
    Task(TaskSpec),
    Shutdown,
}

/// In-process backend: one thread per executor, each owning a plan-built
/// executor state, speaking the same submit/poll protocol as the process
/// backend (minus serialization). Honors the plan's
/// [`WorkerFault`](super::plan::WorkerFault) by dying silently — an
/// in-process stand-in for `kill -9` that exercises the driver's death
/// machinery deterministically in unit tests.
pub struct ThreadBackend {
    factory: RunnerFactory,
    batch_size: usize,
    fault: Option<super::plan::WorkerFault>,
    inputs: Vec<Option<mpsc::Sender<ThreadCmd>>>,
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
    events_tx: mpsc::Sender<ExecutorEvent>,
    events_rx: mpsc::Receiver<ExecutorEvent>,
}

impl ThreadBackend {
    pub fn new(
        executors: usize,
        batch_size: usize,
        fault: Option<super::plan::WorkerFault>,
        factory: RunnerFactory,
    ) -> Self {
        let (events_tx, events_rx) = mpsc::channel();
        Self {
            factory,
            batch_size,
            fault,
            inputs: (0..executors).map(|_| None).collect(),
            handles: (0..executors).map(|_| None).collect(),
            events_tx,
            events_rx,
        }
    }
}

impl ExecutorBackend for ThreadBackend {
    fn name(&self) -> &'static str {
        "thread"
    }

    fn spawn_executor(&mut self, eid: usize) -> Result<()> {
        let (tx, rx) = mpsc::channel::<ThreadCmd>();
        let events = self.events_tx.clone();
        let factory = self.factory.clone();
        let batch_size = self.batch_size;
        let fault = self.fault;
        let handle = std::thread::Builder::new()
            .name(format!("slleval-exec-{eid}"))
            .spawn(move || {
                let mut runner = match factory(eid) {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = events.send(ExecutorEvent::InitError {
                            executor_id: eid,
                            error: format!("{e:#}"),
                        });
                        return;
                    }
                };
                let _ = events.send(ExecutorEvent::Ready { executor_id: eid });
                let mut received = 0usize;
                while let Ok(cmd) = rx.recv() {
                    let spec = match cmd {
                        ThreadCmd::Task(spec) => spec,
                        ThreadCmd::Shutdown => break,
                    };
                    received += 1;
                    if let Some(f) = fault {
                        if f.executor_id == eid && received == f.kill_after_tasks {
                            // Simulated hard death with the same timing as
                            // the process worker's fault hook: the task is
                            // executed (including any worker-side
                            // checkpoint spill) but never reported, then
                            // the executor dies. The driver learns via
                            // Died, exactly like a pipe EOF.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| runner.run(&spec, batch_size)),
                            );
                            let _ = events.send(ExecutorEvent::Died {
                                executor_id: eid,
                                detail: "fault injection: executor killed mid-task".into(),
                            });
                            return;
                        }
                    }
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        runner.run(&spec, batch_size)
                    }));
                    let event = match run {
                        Ok(Ok(result)) => ExecutorEvent::TaskDone { executor_id: eid, result },
                        Ok(Err(e)) => ExecutorEvent::TaskFailed {
                            executor_id: eid,
                            task_id: spec.task_id,
                            error: format!("{e:#}"),
                        },
                        Err(payload) => {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "non-string panic payload".to_string());
                            ExecutorEvent::TaskFailed {
                                executor_id: eid,
                                task_id: spec.task_id,
                                error: format!("executor task panicked: {msg}"),
                            }
                        }
                    };
                    if events.send(event).is_err() {
                        break;
                    }
                }
                runner.finish();
            })
            .context("spawning executor thread")?;
        self.inputs[eid] = Some(tx);
        self.handles[eid] = Some(handle);
        Ok(())
    }

    fn submit(&mut self, eid: usize, spec: &TaskSpec) -> Result<()> {
        let tx = self.inputs[eid].as_ref().context("executor not spawned")?;
        tx.send(ThreadCmd::Task(*spec)).map_err(|_| anyhow::anyhow!("executor {eid} is gone"))
    }

    fn poll(&mut self, timeout: Duration) -> Option<ExecutorEvent> {
        self.events_rx.recv_timeout(timeout).ok()
    }

    fn alive(&self, eid: usize) -> bool {
        self.handles[eid].as_ref().map(|h| !h.is_finished()).unwrap_or(false)
    }

    fn shutdown(&mut self) {
        for tx in self.inputs.iter_mut() {
            if let Some(tx) = tx.take() {
                let _ = tx.send(ThreadCmd::Shutdown);
            }
        }
        for handle in self.handles.iter_mut() {
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for ThreadBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// -------------------------------------------------------- process backend

/// Environment variable naming the worker executable (`slleval`). Used
/// when the driver binary itself has no `worker` subcommand (examples,
/// test harnesses); defaults to `current_exe`.
pub const WORKER_EXE_ENV: &str = "SLLEVAL_WORKER_EXE";

/// Out-of-process backend: one `slleval worker` child per executor,
/// length-prefixed JSON over stdin/stdout. A dedicated reader thread per
/// child converts its stdout frames into [`ExecutorEvent`]s; pipe EOF
/// (the child exited, cleanly or not) becomes [`ExecutorEvent::Died`]
/// unless the driver initiated shutdown.
pub struct ProcessBackend {
    /// The plan, serialized once — a hello frame per worker splices it in
    /// verbatim instead of deep-cloning and re-stringifying the (possibly
    /// corpus-sized) JSON tree per executor.
    plan_text: String,
    batch_size: usize,
    worker_exe: std::path::PathBuf,
    children: Vec<Option<std::process::Child>>,
    stdins: Vec<Option<std::process::ChildStdin>>,
    readers: Vec<Option<std::thread::JoinHandle<()>>>,
    events_tx: mpsc::Sender<ExecutorEvent>,
    events_rx: mpsc::Receiver<ExecutorEvent>,
    /// Set before tearing pipes down so clean-shutdown EOFs are not
    /// reported as deaths.
    closing: Arc<AtomicBool>,
    /// When set, [`ExecutorBackend::shutdown`] is a no-op: the fleet
    /// outlives the job so the next stage of the same run can re-arm the
    /// workers with a `plan` frame instead of respawning processes and
    /// re-shipping a corpus-sized payload.
    keep_alive: bool,
    /// Per-executor "awaiting re-arm ready" flags. While set, the reader
    /// drops everything except the re-arm response — stale frames from a
    /// previous job (an abandoned speculative attempt finishing late)
    /// must not pollute the next job's spend or retry accounting.
    gates: Vec<Arc<AtomicBool>>,
}

impl ProcessBackend {
    /// `worker_exe`: explicit path to the `slleval` binary; falls back to
    /// [`WORKER_EXE_ENV`], then to the current executable.
    pub fn new(
        plan: &TaskPlan,
        executors: usize,
        batch_size: usize,
        worker_exe: Option<std::path::PathBuf>,
    ) -> Result<Self> {
        let worker_exe = match worker_exe {
            Some(p) => p,
            None => match std::env::var_os(WORKER_EXE_ENV) {
                Some(p) => std::path::PathBuf::from(p),
                None => std::env::current_exe().context("locating worker executable")?,
            },
        };
        let (events_tx, events_rx) = mpsc::channel();
        Ok(Self {
            plan_text: plan.to_json().to_string(),
            batch_size,
            worker_exe,
            children: (0..executors).map(|_| None).collect(),
            stdins: (0..executors).map(|_| None).collect(),
            readers: (0..executors).map(|_| None).collect(),
            events_tx,
            events_rx,
            closing: Arc::new(AtomicBool::new(false)),
            keep_alive: false,
            gates: (0..executors).map(|_| Arc::new(AtomicBool::new(false))).collect(),
        })
    }

    /// Keep the worker fleet alive across jobs: `shutdown` becomes a
    /// no-op (teardown happens on drop) and `spawn_executor` re-arms a
    /// still-live worker with a `plan` frame instead of respawning it.
    pub fn set_keep_alive(&mut self, keep: bool) {
        self.keep_alive = keep;
    }

    pub fn executors(&self) -> usize {
        self.children.len()
    }

    /// Point a persistent fleet at the next job's plan. Must be called
    /// before the next `run_plan`; closes the stale-frame window by
    /// gating every live reader *before* draining leftover events.
    pub fn reset_plan(&mut self, plan: &TaskPlan, batch_size: usize) {
        for gate in &self.gates {
            gate.store(true, Ordering::Relaxed);
        }
        while self.events_rx.try_recv().is_ok() {}
        self.plan_text = plan.to_json().to_string();
        self.batch_size = batch_size;
    }
}

/// Parse one worker frame into an event (`None` for unknown types, which
/// are ignored for forward compatibility). Shared with the remote
/// transport ([`super::remote`]), whose readers additionally intercept
/// `heartbeat` and `spill` frames before delegating here.
pub(crate) fn worker_frame_to_event(eid: usize, frame: &Json) -> Option<ExecutorEvent> {
    match frame.str_or("type", "") {
        "ready" => Some(ExecutorEvent::Ready { executor_id: eid }),
        "init_error" => Some(ExecutorEvent::InitError {
            executor_id: eid,
            error: frame.str_or("error", "unknown init error").to_string(),
        }),
        "result" => match TaskResultMsg::from_json(frame) {
            Ok(result) => Some(ExecutorEvent::TaskDone { executor_id: eid, result }),
            Err(e) => Some(ExecutorEvent::Died {
                executor_id: eid,
                detail: format!("malformed result frame: {e:#}"),
            }),
        },
        "task_error" => Some(ExecutorEvent::TaskFailed {
            executor_id: eid,
            task_id: frame.usize_or("task_id", usize::MAX),
            error: frame.str_or("error", "unknown task error").to_string(),
        }),
        _ => None,
    }
}

impl ExecutorBackend for ProcessBackend {
    fn name(&self) -> &'static str {
        "process"
    }

    fn spawn_executor(&mut self, eid: usize) -> Result<()> {
        // Persistent-fleet re-arm: a worker left alive by a previous job
        // (keep_alive shutdown) takes the next plan over its existing
        // pipes and answers with a fresh `ready`.
        if self.readers[eid].as_ref().map(|r| !r.is_finished()).unwrap_or(false) {
            if let Some(stdin) = self.stdins[eid].as_mut() {
                self.gates[eid].store(true, Ordering::Relaxed);
                let plan_msg = format!(
                    "{{\"type\":\"plan\",\"executor_id\":{eid},\"batch_size\":{},\"plan\":{}}}",
                    self.batch_size, self.plan_text
                );
                match write_frame_bytes(stdin, plan_msg.as_bytes()) {
                    Ok(()) => return Ok(()),
                    Err(e) => {
                        // The worker died between jobs; fall through to a
                        // fresh spawn (its EOF event was drained or gated).
                        eprintln!("warning: re-arming worker {eid} failed ({e}); respawning");
                    }
                }
            }
        }
        // Clear dead remnants before a fresh spawn.
        if let Some(mut c) = self.children[eid].take() {
            let _ = c.kill();
            let _ = c.wait();
        }
        self.stdins[eid] = None;
        if let Some(r) = self.readers[eid].take() {
            let _ = r.join();
        }
        self.gates[eid].store(false, Ordering::Relaxed);

        let mut child = std::process::Command::new(&self.worker_exe)
            .arg("worker")
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker process {:?}", self.worker_exe))?;
        let mut stdin = child.stdin.take().context("worker stdin")?;
        let mut stdout = child.stdout.take().context("worker stdout")?;

        // Handshake: ship the plan once; tasks reference ranges into it.
        // The pre-serialized plan text is spliced in verbatim.
        let hello = format!(
            "{{\"type\":\"hello\",\"executor_id\":{eid},\"batch_size\":{},\"plan\":{}}}",
            self.batch_size, self.plan_text
        );
        write_frame_bytes(&mut stdin, hello.as_bytes()).context("writing hello frame")?;

        let events = self.events_tx.clone();
        let closing = self.closing.clone();
        let gate = self.gates[eid].clone();
        let reader = std::thread::Builder::new()
            .name(format!("slleval-worker-rx-{eid}"))
            .spawn(move || loop {
                match read_frame(&mut stdout) {
                    Ok(Some(frame)) => {
                        if gate.load(Ordering::Relaxed) {
                            match frame.str_or("type", "") {
                                "ready" | "init_error" => gate.store(false, Ordering::Relaxed),
                                _ => continue, // stale frame from the previous job
                            }
                        }
                        if let Some(event) = worker_frame_to_event(eid, &frame) {
                            if events.send(event).is_err() {
                                return;
                            }
                        }
                    }
                    Ok(None) => {
                        if !closing.load(Ordering::Relaxed) {
                            let _ = events.send(ExecutorEvent::Died {
                                executor_id: eid,
                                detail: "worker process exited (pipe EOF)".into(),
                            });
                        }
                        return;
                    }
                    Err(e) => {
                        if !closing.load(Ordering::Relaxed) {
                            let _ = events.send(ExecutorEvent::Died {
                                executor_id: eid,
                                detail: format!("worker pipe error: {e:#}"),
                            });
                        }
                        return;
                    }
                }
            })
            .context("spawning worker reader thread")?;

        self.children[eid] = Some(child);
        self.stdins[eid] = Some(stdin);
        self.readers[eid] = Some(reader);
        Ok(())
    }

    fn submit(&mut self, eid: usize, spec: &TaskSpec) -> Result<()> {
        let stdin = self.stdins[eid].as_mut().context("executor not spawned")?;
        write_frame(stdin, &spec.to_json())
            .with_context(|| format!("submitting task to worker {eid}"))
    }

    fn poll(&mut self, timeout: Duration) -> Option<ExecutorEvent> {
        self.events_rx.recv_timeout(timeout).ok()
    }

    fn alive(&self, eid: usize) -> bool {
        match &self.children[eid] {
            // `alive` takes &self; without try_wait treat a spawned child
            // as live — real deaths surface through the reader's EOF.
            Some(_) => self.readers[eid].as_ref().map(|r| !r.is_finished()).unwrap_or(false),
            None => false,
        }
    }

    fn shutdown(&mut self) {
        if self.keep_alive {
            // Persistent fleet: the run keeps the workers for its next
            // stage; real teardown happens on drop.
            return;
        }
        self.closing.store(true, Ordering::Relaxed);
        let shutdown_msg = Json::obj(vec![("type", Json::str("shutdown"))]);
        for stdin in self.stdins.iter_mut() {
            if let Some(mut s) = stdin.take() {
                let _ = write_frame(&mut s, &shutdown_msg);
                // Dropping stdin closes the pipe: a worker blocked on
                // read sees EOF even if it missed the frame.
            }
        }
        // One *collective* grace period: every child got the shutdown at
        // the same instant, so they wind down (cache flushes included)
        // concurrently — the deadline is shared, not per-child, and only
        // stragglers past it are killed.
        // lint:allow(determinism): OS-process grace period is wall-clock by nature
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            let all_done = self
                .children
                .iter_mut()
                .all(|c| c.as_mut().map(|c| matches!(c.try_wait(), Ok(Some(_)))).unwrap_or(true));
            // lint:allow(determinism): comparing against the wall-clock grace deadline
            if all_done || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        for child in self.children.iter_mut() {
            if let Some(mut c) = child.take() {
                if !matches!(c.try_wait(), Ok(Some(_))) {
                    eprintln!("warning: killing worker that ignored shutdown for 15s");
                    let _ = c.kill();
                }
                let _ = c.wait();
            }
        }
        for reader in self.readers.iter_mut() {
            if let Some(r) = reader.take() {
                let _ = r.join();
            }
        }
    }
}

impl Drop for ProcessBackend {
    fn drop(&mut self) {
        self.keep_alive = false;
        self.shutdown();
    }
}

// ------------------------------------------------------------ driver loop

/// Backend-scheduled job outcome: raw JSON rows (kind-specific codec) in
/// row order, plus the telemetry the in-process scheduler reports.
#[derive(Debug)]
pub struct PlanOutput {
    pub rows: Vec<Json>,
    pub executors: Vec<ExecutorStats>,
    pub sched: SchedulerStats,
    pub timeline: Vec<TaskRecord>,
    /// Provider spend summed over every attempt (losing speculative twins
    /// included), reported by the executors themselves.
    pub api_calls: u64,
    pub retries: u64,
    pub cost_usd: f64,
    pub peak_in_flight: usize,
}

struct DriverTask {
    start: usize,
    end: usize,
    completed: bool,
    attempts_failed: usize,
    speculated: bool,
    restored: bool,
    rows: Option<Vec<Json>>,
}

struct InFlightAttempt {
    task_id: usize,
    executor_id: usize,
    attempt: usize,
    speculative: bool,
    started_secs: f64,
}

/// Driver state for one backend-scheduled job.
struct Driver<'a> {
    cfg: &'a SchedulerConfig,
    executors: usize,
    tasks: Vec<DriverTask>,
    queues: Vec<std::collections::VecDeque<usize>>,
    inflight: Vec<InFlightAttempt>,
    ready: Vec<bool>,
    dead: Vec<bool>,
    blacklisted: Vec<bool>,
    failures_per_executor: Vec<usize>,
    exec_stats: Vec<ExecutorStats>,
    rows_done: usize,
    total_rows: usize,
    restored_tasks: usize,
    restored_rows: usize,
    timeline: Vec<TaskRecord>,
    steals: usize,
    speculative_launched: usize,
    speculative_wins: usize,
    retries: usize,
    executor_deaths: usize,
    host_deaths: usize,
    /// Hosts already settled as dead (index per [`ExecutorBackend::host_of`]).
    dead_hosts: std::collections::BTreeSet<usize>,
    api_calls: u64,
    api_retries: u64,
    cost_usd: f64,
    /// Wave gating (adaptive early stopping). `boundary` is the next
    /// row index where the gate is consulted (`total_rows` when ungated
    /// or past the last consult); `deferred` holds `(home executor,
    /// task id)` pairs carved beyond the boundary, ascending by start,
    /// never issued until their wave opens; `settled` is the certified
    /// prefix length once the gate decides `Stop`; `waves` counts
    /// consults taken.
    boundary: usize,
    deferred: std::collections::VecDeque<(usize, usize)>,
    settled: Option<usize>,
    waves: usize,
    fatal: Option<anyhow::Error>,
    t0: Instant,
}

impl Driver<'_> {
    fn live(&self, eid: usize) -> bool {
        self.ready[eid] && !self.dead[eid] && !self.blacklisted[eid]
    }

    /// May still be handed queued work: not dead, not blacklisted —
    /// including executors whose `Ready` has not arrived yet. Queue
    /// placement must not require readiness, or an early death (a worker
    /// OOM-killed during init, before any peer's Ready lands) would find
    /// "no heirs" and fail the whole run instead of costing one executor.
    fn assignable(&self, eid: usize) -> bool {
        !self.dead[eid] && !self.blacklisted[eid]
    }

    fn busy(&self, eid: usize) -> bool {
        self.inflight.iter().any(|f| f.executor_id == eid)
    }

    fn now_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Claim the next task for an idle executor: own queue front, then
    /// steal from the back of the longest other queue, then speculate on
    /// the longest-running unduplicated straggler (same policy as the
    /// in-process scheduler).
    fn claim(&mut self, eid: usize) -> Option<TaskSpec> {
        let mut claimed: Option<(usize, bool)> = self.queues[eid].pop_front().map(|t| (t, false));

        if claimed.is_none() && self.cfg.work_stealing {
            let victim = (0..self.queues.len())
                .filter(|&v| v != eid && !self.queues[v].is_empty())
                .max_by_key(|&v| self.queues[v].len());
            if let Some(v) = victim {
                claimed = self.queues[v].pop_back().map(|t| (t, false));
                self.steals += 1;
            }
        }

        if claimed.is_none() && self.cfg.speculation {
            let total = self.tasks.len() - self.restored_tasks;
            let fresh_done =
                self.tasks.iter().filter(|t| t.completed && !t.restored).count();
            let threshold = (self.cfg.speculation_quantile * total as f64).ceil() as usize;
            if total > 0 && fresh_done >= threshold && fresh_done < total {
                let straggler = self
                    .inflight
                    .iter()
                    .filter(|f| {
                        !f.speculative
                            && !self.tasks[f.task_id].completed
                            && !self.tasks[f.task_id].speculated
                    })
                    .min_by(|a, b| a.started_secs.total_cmp(&b.started_secs))
                    .map(|f| f.task_id);
                if let Some(task_id) = straggler {
                    self.tasks[task_id].speculated = true;
                    self.speculative_launched += 1;
                    claimed = Some((task_id, true));
                }
            }
        }

        let (task_id, speculative) = claimed?;
        let task = &self.tasks[task_id];
        let spec = TaskSpec {
            task_id,
            start: task.start,
            end: task.end,
            attempt: task.attempts_failed + 1,
            speculative,
        };
        self.inflight.push(InFlightAttempt {
            task_id,
            executor_id: eid,
            attempt: spec.attempt,
            speculative,
            started_secs: self.now_secs(),
        });
        Some(spec)
    }

    fn record(&mut self, f: &InFlightAttempt, outcome: TaskOutcome) {
        let task = &self.tasks[f.task_id];
        self.timeline.push(TaskRecord {
            task_id: f.task_id,
            start: task.start,
            end: task.end,
            executor_id: f.executor_id,
            attempt: f.attempt,
            speculative: f.speculative,
            started_at: f.started_secs,
            finished_at: self.now_secs(),
            outcome,
        });
    }

    fn take_inflight(&mut self, eid: usize, task_id: usize) -> Option<InFlightAttempt> {
        let pos = self
            .inflight
            .iter()
            .position(|f| f.executor_id == eid && f.task_id == task_id)?;
        Some(self.inflight.remove(pos))
    }

    /// Enqueue a retry for a failed/lost-to-death task; fatal when the
    /// attempt budget is exhausted or nobody is left to run it.
    fn schedule_retry(&mut self, task_id: usize, not_on: usize, err: anyhow::Error) {
        if self.tasks[task_id].completed {
            return; // a twin already won; the failure costs nothing
        }
        self.tasks[task_id].attempts_failed += 1;
        if self.inflight.iter().any(|f| f.task_id == task_id) {
            return; // a twin attempt is still running; it is the retry
        }
        if self.tasks[task_id].attempts_failed >= self.cfg.max_task_attempts {
            if self.fatal.is_none() {
                let (start, end) = (self.tasks[task_id].start, self.tasks[task_id].end);
                self.fatal = Some(err.context(format!(
                    "task {task_id} [rows {start}..{end}) failed after {} attempts",
                    self.tasks[task_id].attempts_failed
                )));
            }
            return;
        }
        self.retries += 1;
        let n = self.executors;
        let target = (1..=n)
            .map(|d| (not_on + d) % n)
            .find(|&e| self.assignable(e))
            .unwrap_or(not_on);
        self.queues[target].push_back(task_id);
    }

    /// Redistribute a gone executor's queued tasks to the survivors
    /// (including not-yet-ready ones, which take work once initialized).
    fn redistribute_queue(&mut self, eid: usize, err_context: &str) {
        let orphans: Vec<usize> = self.queues[eid].drain(..).collect();
        let heirs: Vec<usize> =
            (0..self.executors).filter(|&e| self.assignable(e)).collect();
        if heirs.is_empty() {
            if !orphans.is_empty() && self.fatal.is_none() {
                self.fatal = Some(anyhow::anyhow!(
                    "no live executors left to take over queued tasks ({err_context})"
                ));
            }
            // Re-queue so a later fatal error message stays accurate.
            self.queues[eid] = orphans.into();
            return;
        }
        for (i, task_id) in orphans.into_iter().enumerate() {
            self.queues[heirs[i % heirs.len()]].push_back(task_id);
        }
    }
}

/// Run a serializable plan's row space through an executor backend under
/// the scheduler policy `cfg`. The driver owns claiming (queues /
/// stealing / speculation), retry + blacklist fault tolerance, executor
/// deaths, restored-range injection, and row-exact reassembly; the
/// backend owns execution. Checkpoint spills are **worker-side**: each
/// executor records its completed tasks into the plan's stage before
/// reporting, so spilled work survives even the driver dying.
///
/// `abort` is an external, read-only stop flag (checked between events);
/// `max_cost_usd` aborts the job once the executors' reported spend
/// crosses the budget (task-granular — a worker does not observe the
/// budget mid-task).
#[allow(clippy::too_many_arguments)]
pub fn run_plan(
    total_rows: usize,
    executors: usize,
    cfg: &SchedulerConfig,
    backend: &mut dyn ExecutorBackend,
    progress: Option<&Progress>,
    restored: Vec<(usize, usize, Vec<Json>)>,
    abort: Option<&AtomicBool>,
    max_cost_usd: Option<f64>,
) -> Result<PlanOutput> {
    run_plan_wave(
        total_rows,
        executors,
        cfg,
        backend,
        progress,
        restored,
        abort,
        max_cost_usd,
        None,
    )
}

/// [`run_plan`] with an optional wave gate (`stopping` in the task
/// JSON). Tasks are carved so none spans a wave boundary; work beyond
/// the next boundary is held back until the gate decides `Continue`; a
/// `Stop` decision settles the job at the boundary — not-yet-issued
/// tasks are cancelled (their rows become `rows_saved`), in-flight
/// attempts wind down through the normal Lost/Abandoned machinery, and
/// the output is the exact row prefix `[0, boundary)`. Restored
/// coverage replays the gate's decisions before any executor spawns,
/// so a resumed run that already satisfies the stopping rule issues
/// zero work.
#[allow(clippy::too_many_arguments)]
pub fn run_plan_wave(
    total_rows: usize,
    executors: usize,
    cfg: &SchedulerConfig,
    backend: &mut dyn ExecutorBackend,
    progress: Option<&Progress>,
    restored: Vec<(usize, usize, Vec<Json>)>,
    abort: Option<&AtomicBool>,
    max_cost_usd: Option<f64>,
    gate: Option<&WaveGate<'_, Json>>,
) -> Result<PlanOutput> {
    cfg.validate()?;
    let executors = executors.max(1);

    let mut driver = Driver {
        cfg,
        executors,
        tasks: Vec::new(),
        queues: (0..executors).map(|_| Default::default()).collect(),
        inflight: Vec::new(),
        ready: vec![false; executors],
        dead: vec![false; executors],
        blacklisted: vec![false; executors],
        failures_per_executor: vec![0; executors],
        exec_stats: (0..executors)
            .map(|eid| ExecutorStats { executor_id: eid, ..Default::default() })
            .collect(),
        rows_done: 0,
        total_rows,
        restored_tasks: 0,
        restored_rows: 0,
        timeline: Vec::new(),
        steals: 0,
        speculative_launched: 0,
        speculative_wins: 0,
        retries: 0,
        executor_deaths: 0,
        host_deaths: 0,
        dead_hosts: Default::default(),
        api_calls: 0,
        api_retries: 0,
        cost_usd: 0.0,
        boundary: total_rows,
        deferred: Default::default(),
        settled: None,
        waves: 0,
        fatal: None,
        t0: Instant::now(), // lint:allow(determinism): wall-clock anchor for timeline telemetry
    };

    // Validate + inject restored ranges as pre-completed tasks (identical
    // contract to `run_scheduled_ext`).
    let mut restored = restored;
    restored.sort_by_key(|(start, _, _)| *start);
    {
        let mut cursor = 0usize;
        for (start, end, rows) in &restored {
            anyhow::ensure!(
                start < end && *end <= total_rows,
                "restored range [{start}, {end}) out of bounds for {total_rows} rows"
            );
            anyhow::ensure!(*start >= cursor, "restored ranges overlap at row {start}");
            anyhow::ensure!(
                rows.len() == end - start,
                "restored range [{start}, {end}) carries {} rows",
                rows.len()
            );
            cursor = *end;
        }
    }
    let restored_spans: Vec<(usize, usize)> =
        restored.iter().map(|(s, e, _)| (*s, *e)).collect();
    for (start, end, rows) in restored {
        driver.tasks.push(DriverTask {
            start,
            end,
            completed: true,
            attempts_failed: 0,
            speculated: false,
            restored: true,
            rows: Some(rows),
        });
        driver.restored_tasks += 1;
        driver.restored_rows += end - start;
        driver.rows_done += end - start;
        if let Some(p) = progress {
            p.add(end - start);
        }
    }

    // Carve fresh tasks over the uncovered gaps (same layout math as the
    // in-process scheduler: near-equal contiguous ranges over
    // `executors * tasks_per_executor` slots, assigned contiguously).
    let n_slots = executors * cfg.tasks_per_executor;
    let mut gaps: Vec<(usize, usize)> = Vec::new();
    {
        let mut cursor = 0usize;
        for &(start, end) in &restored_spans {
            if start > cursor {
                gaps.push((cursor, start));
            }
            cursor = end;
        }
        if cursor < total_rows {
            gaps.push((cursor, total_rows));
        }
    }
    if restored_spans.is_empty() {
        // Uniform carve matching `DataFrame::partition_ranges`.
        let base = total_rows / n_slots;
        let extra = total_rows % n_slots;
        let mut start = 0usize;
        for slot in 0..n_slots {
            let size = base + usize::from(slot < extra);
            if size > 0 {
                let id = driver.tasks.len();
                driver.tasks.push(DriverTask {
                    start,
                    end: start + size,
                    completed: false,
                    attempts_failed: 0,
                    speculated: false,
                    restored: false,
                    rows: None,
                });
                let home = slot * executors / n_slots;
                driver.queues[home].push_back(id);
            }
            start += size;
        }
    } else {
        let total_gap: usize = gaps.iter().map(|(s, e)| e - s).sum();
        let mut slot = 0usize;
        for &(gap_start, gap_end) in &gaps {
            let len = gap_end - gap_start;
            let parts = (len * n_slots).div_ceil(total_gap.max(1)).clamp(1, len.max(1));
            if len == 0 {
                continue;
            }
            let base = len / parts;
            let rem = len % parts;
            let mut start = gap_start;
            for i in 0..parts {
                let end = start + base + usize::from(i < rem);
                let id = driver.tasks.len();
                driver.tasks.push(DriverTask {
                    start,
                    end,
                    completed: false,
                    attempts_failed: 0,
                    speculated: false,
                    restored: false,
                    rows: None,
                });
                let home = (slot * executors / n_slots).min(executors - 1);
                driver.queues[home].push_back(id);
                slot += 1;
                start = end;
            }
        }
    }

    // Wave-align the carve: split every fresh task at each wave boundary
    // (pure config arithmetic — identical on resume) so completed
    // coverage below a boundary is exactly the row prefix the gate
    // decides over, then park everything beyond the first boundary.
    if let Some(g) = gate {
        driver.boundary = g.first.max(1).min(total_rows);
        let first = driver.boundary;
        let step = g.step.max(1);
        let mut parked: Vec<(usize, usize)> = Vec::new();
        for home in 0..executors {
            let queued: Vec<usize> = driver.queues[home].drain(..).collect();
            for id in queued {
                let mut pieces = vec![id];
                loop {
                    let last = pieces[pieces.len() - 1];
                    let (start, end) = (driver.tasks[last].start, driver.tasks[last].end);
                    let next_b = if start < first {
                        first
                    } else {
                        first + (start - first) / step * step + step
                    };
                    if end <= next_b {
                        break;
                    }
                    let child = driver.tasks.len();
                    driver.tasks.push(DriverTask {
                        start: next_b,
                        end,
                        completed: false,
                        attempts_failed: 0,
                        speculated: false,
                        restored: false,
                        rows: None,
                    });
                    driver.tasks[last].end = next_b;
                    pieces.push(child);
                }
                for id in pieces {
                    if driver.tasks[id].start < first {
                        driver.queues[home].push_back(id);
                    } else {
                        parked.push((home, id));
                    }
                }
            }
        }
        parked.sort_by_key(|&(_, id)| driver.tasks[id].start);
        driver.deferred = parked.into();
        // Replay the gate over restored coverage before spawning
        // anything: a resumed run whose prefix already satisfies the
        // stopping rule must decide Stop here and issue zero work.
        consult_gate(&mut driver, g);
        if driver.fatal.is_some() {
            return finish(driver, backend, true);
        }
    }

    // Fully restored (or empty, or settled by restored replay) job:
    // nothing to spawn.
    if driver.settled.is_some() || driver.rows_done == total_rows {
        return finish(driver, backend, false);
    }

    for eid in 0..executors {
        backend.spawn_executor(eid)?;
    }
    // Handshake deadline: a spawned executor that stays alive but never
    // answers the protocol (a misconfigured worker binary eating stdin)
    // must fail the job with a diagnosis, not hang the driver forever.
    // lint:allow(determinism): handshake timeout guards real I/O, wall-clock by design
    let ready_deadline = Instant::now() + Duration::from_secs(60);

    // ---------------------------------------------------------- event loop
    while driver.fatal.is_none()
        && driver.settled.is_none()
        && driver.rows_done < driver.total_rows
    {
        // lint:allow(determinism): comparing against the wall-clock handshake deadline
        if Instant::now() > ready_deadline {
            if let Some(eid) =
                (0..executors).find(|&e| !driver.ready[e] && !driver.dead[e] && backend.alive(e))
            {
                driver.fatal = Some(anyhow::anyhow!(
                    "executor {eid} never completed the {} handshake within 60s \
                     (is the worker executable actually `slleval`?)",
                    backend.name()
                ));
                break;
            }
        }
        // External abort (budget watchdogs, Ctrl-C).
        if let Some(flag) = abort {
            if flag.load(Ordering::Relaxed) && driver.rows_done < driver.total_rows {
                driver.fatal = Some(anyhow::anyhow!(
                    "run aborted with {}/{} rows complete",
                    driver.rows_done,
                    driver.total_rows
                ));
                break;
            }
        }

        // Dispatch to every idle live executor.
        for eid in 0..executors {
            if !driver.live(eid) || driver.busy(eid) || !backend.alive(eid) {
                continue;
            }
            if let Some(spec) = driver.claim(eid) {
                if let Err(e) = backend.submit(eid, &spec) {
                    // Unreachable executor: roll the claim back and let
                    // the death settle through the event (or directly).
                    if let Some(f) = driver.take_inflight(eid, spec.task_id) {
                        if spec.speculative {
                            driver.tasks[spec.task_id].speculated = false;
                            driver.speculative_launched -= 1;
                        } else {
                            driver.queues[eid].push_front(spec.task_id);
                        }
                        driver.record(&f, TaskOutcome::Abandoned);
                    }
                    let detail = format!("submit failed: {e:#}");
                    settle_death_and_host(&mut driver, &*backend, eid, &detail);
                }
            }
        }

        // Stall check: nothing running, nothing claimable.
        if driver.inflight.is_empty() {
            let queued: usize = driver.queues.iter().map(|q| q.len()).sum();
            let any_live = (0..executors).any(|e| driver.live(e) && backend.alive(e));
            let any_pending_ready =
                (0..executors).any(|e| !driver.ready[e] && !driver.dead[e] && backend.alive(e));
            if !any_live && !any_pending_ready {
                driver.fatal = Some(anyhow::anyhow!(
                    "no live executors left ({} dead, {} blacklisted) with {}/{} rows done",
                    driver.executor_deaths,
                    driver.blacklisted.iter().filter(|&&b| b).count(),
                    driver.rows_done,
                    driver.total_rows
                ));
                break;
            }
            if queued == 0 && any_live && !any_pending_ready {
                driver.fatal = Some(anyhow::anyhow!(
                    "scheduler stalled with {}/{} rows done",
                    driver.rows_done,
                    driver.total_rows
                ));
                break;
            }
        }

        let Some(event) = backend.poll(Duration::from_millis(20)) else { continue };
        match event {
            ExecutorEvent::Ready { executor_id } => {
                driver.ready[executor_id] = true;
            }
            ExecutorEvent::InitError { executor_id, error } => {
                driver.fatal =
                    Some(anyhow::anyhow!("executor {executor_id} failed to initialize: {error}"));
            }
            ExecutorEvent::TaskDone { executor_id, result } => {
                // Spend covers every attempt, winners and losers alike.
                driver.api_calls += result.api_calls;
                driver.api_retries += result.retries;
                driver.cost_usd += result.cost_usd;
                let st = &mut driver.exec_stats[executor_id];
                st.rows_processed += result.rows_processed;
                st.batches += result.batches;
                st.busy_secs += result.busy_secs;
                st.peak_in_flight = st.peak_in_flight.max(result.peak_in_flight);

                let Some(f) = driver.take_inflight(executor_id, result.task_id) else {
                    continue; // stale frame from a settled executor
                };
                let task_id = result.task_id;
                let (t_start, t_end, completed) = {
                    let t = &driver.tasks[task_id];
                    (t.start, t.end, t.completed)
                };
                if completed {
                    driver.record(&f, TaskOutcome::Lost);
                } else if result.rows.len() != t_end - t_start
                    || (result.start, result.end) != (t_start, t_end)
                {
                    driver.record(&f, TaskOutcome::Failed);
                    driver.failures_per_executor[executor_id] += 1;
                    maybe_blacklist(&mut driver, executor_id);
                    driver.schedule_retry(
                        task_id,
                        executor_id,
                        anyhow::anyhow!(
                            "executor {executor_id} returned {} rows for task \
                             [{t_start}, {t_end})",
                            result.rows.len(),
                        ),
                    );
                } else {
                    driver.tasks[task_id].completed = true;
                    driver.tasks[task_id].rows = Some(result.rows);
                    let n = t_end - t_start;
                    driver.rows_done += n;
                    if let Some(p) = progress {
                        p.add(n);
                    }
                    if f.speculative {
                        driver.speculative_wins += 1;
                    }
                    driver.record(&f, TaskOutcome::Won);
                    if let Some(g) = gate {
                        consult_gate(&mut driver, g);
                    }
                    if let Some(budget) = max_cost_usd {
                        if driver.cost_usd > budget
                            && driver.settled.is_none()
                            && driver.rows_done < driver.total_rows
                        {
                            driver.fatal = Some(anyhow::anyhow!(
                                "run aborted: cost ${:.4} exceeded budget ${budget:.4} \
                                 with {}/{} rows complete",
                                driver.cost_usd,
                                driver.rows_done,
                                driver.total_rows
                            ));
                        }
                    }
                }
            }
            ExecutorEvent::TaskFailed { executor_id, task_id, error } => {
                if let Some(f) = driver.take_inflight(executor_id, task_id) {
                    driver.record(&f, TaskOutcome::Failed);
                }
                driver.failures_per_executor[executor_id] += 1;
                maybe_blacklist(&mut driver, executor_id);
                if task_id < driver.tasks.len() {
                    driver.schedule_retry(task_id, executor_id, anyhow::anyhow!("{error}"));
                }
            }
            ExecutorEvent::Died { executor_id, detail } => {
                settle_death_and_host(&mut driver, &*backend, executor_id, &detail);
            }
        }
    }

    let had_fatal = driver.fatal.is_some();
    finish(driver, backend, had_fatal)
}

/// Rows of completed coverage below `b` (a restored range overhanging
/// `b` counts only its below-`b` part). Tasks tile disjoint ranges, so
/// this equals `b` exactly when the whole prefix `[0, b)` is complete.
fn covered_prefix_rows(driver: &Driver<'_>, b: usize) -> usize {
    driver
        .tasks
        .iter()
        .filter(|t| t.completed)
        .map(|t| t.end.min(b).saturating_sub(t.start.min(b)))
        .sum()
}

/// Consult the wave gate: once completed coverage reaches the current
/// boundary, assemble the exact in-order row prefix and ask `decide`.
/// `Continue` releases the next wave's deferred tasks into their home
/// queues; `Stop` settles the job at the boundary and cancels every
/// not-yet-issued task (in-flight attempts wind down through the normal
/// Lost/Abandoned machinery). Loops because restored coverage can span
/// several waves at once.
fn consult_gate(driver: &mut Driver<'_>, gate: &WaveGate<'_, Json>) {
    loop {
        if driver.settled.is_some() || driver.fatal.is_some() {
            return;
        }
        let b = driver.boundary;
        if b >= driver.total_rows {
            return; // the final wave finishes by exhausting rows, not by consult
        }
        if covered_prefix_rows(driver, b) < b {
            return;
        }
        let wave = driver.waves;
        driver.waves += 1;
        let mut parts: Vec<(usize, &Vec<Json>)> = driver
            .tasks
            .iter()
            .filter(|t| t.completed && t.start < b)
            .filter_map(|t| t.rows.as_ref().map(|rows| (t.start, rows)))
            .collect();
        parts.sort_by_key(|&(start, _)| start);
        let mut prefix: Vec<&Json> = Vec::with_capacity(b);
        for (start, rows) in parts {
            prefix.extend(rows.iter().take(b - start));
        }
        debug_assert_eq!(prefix.len(), b);
        match (gate.decide)(wave, &prefix) {
            Ok(WaveDecision::Continue) => {
                driver.boundary = (b + gate.step.max(1)).min(driver.total_rows);
                while let Some(&(home, id)) = driver.deferred.front() {
                    if driver.tasks[id].start >= driver.boundary {
                        break;
                    }
                    let _ = driver.deferred.pop_front();
                    // A home lost to death/blacklist after the carve:
                    // hand its wave work to the next assignable peer.
                    let target = if driver.assignable(home) {
                        home
                    } else {
                        (0..driver.executors)
                            .map(|d| (home + d) % driver.executors)
                            .find(|&e| driver.assignable(e))
                            .unwrap_or(home)
                    };
                    driver.queues[target].push_back(id);
                }
            }
            Ok(WaveDecision::Stop) => {
                driver.settled = Some(b);
                while let Some((_, id)) = driver.deferred.pop_front() {
                    // Cancelled before issue: empty the range so
                    // reassembly skips it — these rows are the run's
                    // `rows_saved`.
                    let start = driver.tasks[id].start;
                    driver.tasks[id].end = start;
                }
                return;
            }
            Err(e) => {
                if driver.fatal.is_none() {
                    driver.fatal =
                        Some(e.context(format!("wave gate failed at wave {wave}")));
                }
                return;
            }
        }
    }
}

/// Blacklist an executor whose failure count crossed the threshold.
fn maybe_blacklist(driver: &mut Driver<'_>, eid: usize) {
    if driver.failures_per_executor[eid] >= driver.cfg.blacklist_after
        && !driver.blacklisted[eid]
    {
        driver.blacklisted[eid] = true;
        driver.redistribute_queue(eid, "executor blacklisted after repeated failures");
    }
}

/// Settle an executor death *and* its failure domain: when the backend
/// places multiple executors per host ([`ExecutorBackend::host_of`]),
/// one lost connection means the whole host is gone — its peers' sockets
/// would each have to ride out a heartbeat timeout individually, so the
/// driver settles them all at once and counts one `host_death`.
fn settle_death_and_host(
    driver: &mut Driver<'_>,
    backend: &dyn ExecutorBackend,
    eid: usize,
    detail: &str,
) {
    settle_death(driver, eid, detail);
    let Some(host) = backend.host_of(eid) else { return };
    if driver.dead_hosts.insert(host) {
        driver.host_deaths += 1;
        for peer in 0..driver.executors {
            if peer != eid && backend.host_of(peer) == Some(host) && !driver.dead[peer] {
                settle_death(driver, peer, &format!("host {host} died: {detail}"));
            }
        }
    }
}

/// Fold an executor death into the fault-tolerance machinery: count it,
/// stop scheduling onto it, retry its in-flight task elsewhere, and hand
/// its queue to the survivors.
fn settle_death(driver: &mut Driver<'_>, eid: usize, detail: &str) {
    if driver.dead[eid] {
        return;
    }
    driver.dead[eid] = true;
    driver.executor_deaths += 1;
    driver.blacklisted[eid] = true;
    eprintln!("warning: executor {eid} died ({detail}); redistributing its work");
    let mut lost: Vec<InFlightAttempt> = Vec::new();
    while let Some(pos) = driver.inflight.iter().position(|f| f.executor_id == eid) {
        lost.push(driver.inflight.remove(pos));
    }
    for f in lost {
        driver.record(&f, TaskOutcome::Failed);
        driver.schedule_retry(
            f.task_id,
            eid,
            anyhow::anyhow!("executor {eid} died mid-task: {detail}"),
        );
    }
    driver.redistribute_queue(eid, detail);
}

/// Assemble the final output (or surface the fatal error) and shut the
/// backend down.
fn finish(
    mut driver: Driver<'_>,
    backend: &mut dyn ExecutorBackend,
    had_fatal: bool,
) -> Result<PlanOutput> {
    // Attempts still in flight when the job settled (a won twin's
    // straggler, or any attempt at abort time) are abandoned: their rows
    // never arrive, but the duplicated work is accounted as wasted.
    while let Some(f) = driver.inflight.pop() {
        driver.record(&f, TaskOutcome::Abandoned);
    }
    backend.shutdown();
    if had_fatal {
        if let Some(e) = driver.fatal.take() {
            return Err(e);
        }
    }

    // A gate-settled job certifies exactly the prefix [0, settled_end);
    // cancelled tasks carry empty ranges, and restored coverage past the
    // stop boundary is clipped so resume never widens the output.
    let settled_end = driver.settled.unwrap_or(driver.total_rows);
    let mut parts: Vec<(usize, usize, Vec<Json>)> = Vec::with_capacity(driver.tasks.len());
    for (id, task) in driver.tasks.iter_mut().enumerate() {
        if task.start == task.end || task.start >= settled_end {
            continue;
        }
        let Some(rows) = task.rows.take() else {
            bail!(
                "scheduler invariant violated: task {id} [{}, {}) never completed",
                task.start,
                task.end
            );
        };
        parts.push((task.start, task.end, rows));
    }
    parts.sort_by_key(|(start, _, _)| *start);
    let mut rows = Vec::with_capacity(settled_end);
    let mut cursor = 0usize;
    for (start, end, mut part) in parts {
        anyhow::ensure!(
            start == cursor && part.len() == end - start,
            "scheduler invariant violated: task range [{start}, {end}) does not tile the \
             frame at row {cursor}"
        );
        if end > settled_end {
            part.truncate(settled_end - start);
        }
        rows.extend(part);
        cursor = end.min(settled_end);
    }
    anyhow::ensure!(
        cursor == settled_end,
        "scheduler invariant violated: covered {cursor} of {settled_end} certified rows"
    );

    let mut sched = SchedulerStats {
        tasks: driver.tasks.iter().filter(|t| t.start != t.end).count(),
        steals: driver.steals,
        speculative_launched: driver.speculative_launched,
        speculative_wins: driver.speculative_wins,
        splits: 0,
        retries: driver.retries,
        restored_tasks: driver.restored_tasks,
        restored_rows: driver.restored_rows,
        executor_deaths: driver.executor_deaths,
        host_deaths: driver.host_deaths,
        blacklisted_executors: (0..driver.executors)
            .filter(|&e| driver.blacklisted[e])
            .collect(),
        wasted_rows: driver
            .timeline
            .iter()
            .filter(|r| matches!(r.outcome, TaskOutcome::Lost | TaskOutcome::Abandoned))
            .map(|r| r.end - r.start)
            .sum(),
        rows_evaluated: settled_end,
        rows_saved: driver.total_rows - settled_end,
        waves: driver.waves,
        ..Default::default()
    };
    let wins: Vec<f64> = driver
        .timeline
        .iter()
        .filter(|r| r.outcome == TaskOutcome::Won)
        .map(|r| r.finished_at - r.started_at)
        .collect();
    if !wins.is_empty() {
        sched.longest_task_secs = wins.iter().cloned().fold(0.0, f64::max);
        sched.mean_task_secs = wins.iter().sum::<f64>() / wins.len() as f64;
        sched.skew_ratio = if sched.mean_task_secs > 0.0 {
            sched.longest_task_secs / sched.mean_task_secs
        } else {
            1.0
        };
    }
    let peak_in_flight =
        driver.exec_stats.iter().map(|e| e.peak_in_flight).max().unwrap_or(0);
    Ok(PlanOutput {
        rows,
        executors: driver.exec_stats,
        sched,
        timeline: driver.timeline,
        api_calls: driver.api_calls,
        retries: driver.api_retries,
        cost_usd: driver.cost_usd,
        peak_in_flight,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_round_trips() {
        for kind in [BackendKind::Thread, BackendKind::Process, BackendKind::Remote] {
            assert_eq!(BackendKind::from_str(kind.as_str()).unwrap(), kind);
        }
        assert!(BackendKind::from_str("bogus").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Thread);
    }

    // The frame codec round-trip / torn-frame tests live with the codec
    // in `sched::wire`.

    #[test]
    fn task_spec_and_result_round_trip() {
        let spec =
            TaskSpec { task_id: 3, start: 10, end: 20, attempt: 2, speculative: true };
        assert_eq!(TaskSpec::from_json(&spec.to_json()).unwrap(), spec);

        let msg = TaskResultMsg {
            task_id: 3,
            start: 10,
            end: 12,
            attempt: 2,
            speculative: false,
            rows: vec![Json::num(1.0), Json::Null],
            rows_processed: 2,
            batches: 1,
            busy_secs: 0.5,
            peak_in_flight: 4,
            api_calls: 7,
            retries: 1,
            cost_usd: 0.25,
        };
        let restored = TaskResultMsg::from_json(&msg.to_json()).unwrap();
        assert_eq!(restored.task_id, 3);
        assert_eq!(restored.rows, msg.rows);
        assert_eq!(restored.api_calls, 7);
        assert_eq!(restored.cost_usd, 0.25);
    }

    // ------------------------------------------------- wave-gate driver

    use std::sync::atomic::AtomicUsize;

    /// Echoes each row's index back as its result; counts executions so
    /// replay tests can assert zero re-inference.
    struct RowEcho(Arc<AtomicUsize>);

    impl PlanTaskRunner for RowEcho {
        fn run(&mut self, spec: &TaskSpec, _batch_size: usize) -> Result<TaskResultMsg> {
            self.0.fetch_add(spec.end - spec.start, Ordering::SeqCst);
            Ok(TaskResultMsg {
                task_id: spec.task_id,
                start: spec.start,
                end: spec.end,
                attempt: spec.attempt,
                speculative: spec.speculative,
                rows: (spec.start..spec.end).map(|i| Json::num(i as f64)).collect(),
                rows_processed: spec.end - spec.start,
                batches: 1,
                busy_secs: 0.0,
                peak_in_flight: 1,
                api_calls: (spec.end - spec.start) as u64,
                retries: 0,
                cost_usd: 0.0,
            })
        }
    }

    fn echo_factory(rows_run: Arc<AtomicUsize>) -> RunnerFactory {
        Arc::new(move |_eid| Ok(Box::new(RowEcho(rows_run.clone())) as Box<dyn PlanTaskRunner>))
    }

    fn assert_index_rows(rows: &[Json], n: usize) {
        assert_eq!(rows.len(), n);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.as_f64().unwrap() as usize, i, "row {i} out of order");
        }
    }

    #[test]
    fn backend_wave_gate_stops_with_exact_prefix_and_accounting() {
        let rows_run = Arc::new(AtomicUsize::new(0));
        let decide = |_wave: usize, prefix: &[&Json]| -> Result<WaveDecision> {
            // The gate must always see the exact in-order prefix.
            for (i, row) in prefix.iter().enumerate() {
                assert_eq!(row.as_f64().unwrap() as usize, i);
            }
            Ok(if prefix.len() >= 100 { WaveDecision::Stop } else { WaveDecision::Continue })
        };
        let gate = WaveGate { first: 50, step: 50, decide: &decide };
        let mut backend = ThreadBackend::new(2, 8, None, echo_factory(rows_run.clone()));
        let cfg = SchedulerConfig::default();
        let out = run_plan_wave(
            200,
            2,
            &cfg,
            &mut backend,
            None,
            Vec::new(),
            None,
            None,
            Some(&gate),
        )
        .unwrap();
        assert_index_rows(&out.rows, 100);
        assert_eq!(out.sched.rows_evaluated, 100);
        assert_eq!(out.sched.rows_saved, 100);
        assert_eq!(out.sched.rows_evaluated + out.sched.rows_saved, 200);
        assert_eq!(out.sched.waves, 2);
        // No executor ever ran a task beyond the settled boundary.
        assert_eq!(rows_run.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn backend_wave_gate_replays_restored_prefix_without_running_anything() {
        let rows_run = Arc::new(AtomicUsize::new(0));
        let decide = |_wave: usize, prefix: &[&Json]| -> Result<WaveDecision> {
            Ok(if prefix.len() >= 100 { WaveDecision::Stop } else { WaveDecision::Continue })
        };
        let gate = WaveGate { first: 50, step: 50, decide: &decide };
        // Restored coverage overhangs the stop boundary: the replay must
        // decide Stop at 100 and clip the overhang, issuing zero work.
        let restored: Vec<(usize, usize, Vec<Json>)> =
            vec![(0, 120, (0..120).map(|i| Json::num(i as f64)).collect())];
        let mut backend = ThreadBackend::new(2, 8, None, echo_factory(rows_run.clone()));
        let cfg = SchedulerConfig::default();
        let out = run_plan_wave(
            200,
            2,
            &cfg,
            &mut backend,
            None,
            restored,
            None,
            None,
            Some(&gate),
        )
        .unwrap();
        assert_index_rows(&out.rows, 100);
        assert_eq!(out.sched.waves, 2);
        assert_eq!(out.sched.rows_evaluated, 100);
        assert_eq!(out.sched.rows_saved, 100);
        assert_eq!(rows_run.load(Ordering::SeqCst), 0, "resume must re-infer nothing");
    }

    #[test]
    fn backend_wave_gate_that_never_stops_matches_ungated_run() {
        let decide =
            |_wave: usize, _prefix: &[&Json]| -> Result<WaveDecision> { Ok(WaveDecision::Continue) };
        let gate = WaveGate { first: 40, step: 40, decide: &decide };
        let cfg = SchedulerConfig::default();
        let mut backend = ThreadBackend::new(2, 8, None, echo_factory(Arc::default()));
        let gated = run_plan_wave(
            130,
            2,
            &cfg,
            &mut backend,
            None,
            Vec::new(),
            None,
            None,
            Some(&gate),
        )
        .unwrap();
        assert_index_rows(&gated.rows, 130);
        // Boundaries 40, 80, 120 each consult; 160 clamps to 130 and the
        // final wave finishes by exhausting rows.
        assert_eq!(gated.sched.waves, 3);
        assert_eq!(gated.sched.rows_evaluated, 130);
        assert_eq!(gated.sched.rows_saved, 0);

        let mut backend = ThreadBackend::new(2, 8, None, echo_factory(Arc::default()));
        let plain =
            run_plan(130, 2, &cfg, &mut backend, None, Vec::new(), None, None).unwrap();
        assert_eq!(plain.rows, gated.rows);
        assert_eq!(plain.sched.rows_evaluated, 130);
        assert_eq!(plain.sched.rows_saved, 0);
        assert_eq!(plain.sched.waves, 0);
    }

    #[test]
    fn backend_wave_gate_error_fails_the_job() {
        let decide = |_wave: usize, _prefix: &[&Json]| -> Result<WaveDecision> {
            anyhow::bail!("ci recompute exploded")
        };
        let gate = WaveGate { first: 30, step: 30, decide: &decide };
        let cfg = SchedulerConfig::default();
        let mut backend = ThreadBackend::new(2, 8, None, echo_factory(Arc::default()));
        let err = run_plan_wave(
            90,
            2,
            &cfg,
            &mut backend,
            None,
            Vec::new(),
            None,
            None,
            Some(&gate),
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("wave gate failed at wave 0"), "{msg}");
        assert!(msg.contains("ci recompute exploded"), "{msg}");
    }
}
