//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The offline build environment has no crates.io registry, so this crate
//! provides the exact surface the workspace uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Errors are represented as a rendered message chain (outermost
//! context first); `downcast` and backtraces are intentionally out of
//! scope.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate, so `anyhow::Result<T>` and `Result<T, E>` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A rendered error chain: `chain[0]` is the outermost message, later
/// entries are successive causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (the `.context(...)` operation).
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow convention).
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(self.root_message())
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

/// Sealed conversion helper so `Context` covers both `Result<T, E>` for any
/// std error `E` and `Result<T, anyhow::Error>` (same device as the real
/// crate's private `ext::StdError`).
mod ext {
    use super::Error;

    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

impl<T, E: ext::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(context()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<u32, std::io::Error> = Ok(7);
        let v = r.with_context(|| panic!("must not run")).unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros_build_errors() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too large: 12");
        assert_eq!(format!("{}", inner(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", inner(5).unwrap_err()), "fell through with 5");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
