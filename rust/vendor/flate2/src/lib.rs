//! Vendored, dependency-free subset of the `flate2` gzip API.
//!
//! The offline build has no crates.io registry, so this crate provides the
//! exact surface deltalite uses: [`write::GzEncoder`], [`read::GzDecoder`],
//! and [`Compression`]. Streams are RFC 1952 gzip containers whose deflate
//! payload uses **stored (uncompressed) blocks only** — a valid gzip any
//! external tool can read (`zcat` works), with CRC-32 and length verified
//! on decode. The real crate's compression ratios are out of scope; the
//! cache's storage-overhead numbers therefore measure raw JSONL size.
//!
//! The decoder accepts only what this encoder emits (plus standard header
//! variations: FEXTRA/FNAME/FCOMMENT/FHCRC fields are skipped). Compressed
//! deflate block types produce an explanatory error instead of garbage.

use std::io::{self, Read, Write};

/// Compression level marker (accepted and ignored: all levels store).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn best() -> Compression {
        Compression(9)
    }

    pub fn none() -> Compression {
        Compression(0)
    }

    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

/// CRC-32 (IEEE 802.3, reflected) lookup table, built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { 0xEDB8_8320 ^ (crc >> 1) } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &byte in data {
        crc = CRC_TABLE[((crc ^ byte as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

pub mod write {
    use super::*;

    /// Gzip encoder: buffers written bytes and emits the full container on
    /// [`GzEncoder::finish`].
    pub struct GzEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> GzEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> GzEncoder<W> {
            GzEncoder { inner, buf: Vec::new() }
        }

        /// Write the gzip stream and return the inner writer.
        pub fn finish(mut self) -> io::Result<W> {
            // Header: magic, CM=8 (deflate), no flags, MTIME=0, XFL=0,
            // OS=255 (unknown).
            self.inner.write_all(&[0x1F, 0x8B, 0x08, 0, 0, 0, 0, 0, 0, 0xFF])?;
            // Deflate payload: stored blocks of at most 0xFFFF bytes. Each
            // block starts byte-aligned: BFINAL in bit 0, BTYPE=00, rest
            // of the byte is padding.
            let mut chunks = self.buf.chunks(0xFFFF).peekable();
            if chunks.peek().is_none() {
                // Empty payload still needs one final stored block.
                self.inner.write_all(&[0x01, 0, 0, 0xFF, 0xFF])?;
            }
            while let Some(chunk) = chunks.next() {
                let final_block = chunks.peek().is_none();
                let len = chunk.len() as u16;
                self.inner.write_all(&[u8::from(final_block)])?;
                self.inner.write_all(&len.to_le_bytes())?;
                self.inner.write_all(&(!len).to_le_bytes())?;
                self.inner.write_all(chunk)?;
            }
            // Trailer: CRC-32 and ISIZE, little endian.
            self.inner.write_all(&crc32(&self.buf).to_le_bytes())?;
            self.inner.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for GzEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use super::*;

    /// Gzip decoder: reads and validates the whole stream on first read,
    /// then serves the decoded bytes.
    pub struct GzDecoder<R: Read> {
        inner: Option<R>,
        decoded: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> GzDecoder<R> {
        pub fn new(inner: R) -> GzDecoder<R> {
            GzDecoder { inner: Some(inner), decoded: Vec::new(), pos: 0 }
        }
    }

    fn bad(msg: &str) -> io::Error {
        io::Error::new(io::ErrorKind::InvalidData, format!("gzip: {msg}"))
    }

    /// Consume `n` bytes of `raw` at `*pos`.
    fn take<'a>(raw: &'a [u8], pos: &mut usize, n: usize) -> io::Result<&'a [u8]> {
        if raw.len() - *pos < n {
            return Err(bad("truncated stream"));
        }
        let s = &raw[*pos..*pos + n];
        *pos += n;
        Ok(s)
    }

    fn decode_all(raw: &[u8]) -> io::Result<Vec<u8>> {
        let mut pos = 0usize;

        let header = take(raw, &mut pos, 10)?;
        if header[0] != 0x1F || header[1] != 0x8B {
            return Err(bad("bad magic"));
        }
        if header[2] != 8 {
            return Err(bad("unknown compression method"));
        }
        let flags = header[3];
        if flags & 0x04 != 0 {
            // FEXTRA: two-byte length then payload.
            let len = take(raw, &mut pos, 2)?;
            let len = u16::from_le_bytes([len[0], len[1]]) as usize;
            take(raw, &mut pos, len)?;
        }
        for flag in [0x08u8, 0x10] {
            // FNAME / FCOMMENT: zero-terminated strings.
            if flags & flag != 0 {
                while take(raw, &mut pos, 1)?[0] != 0 {}
            }
        }
        if flags & 0x02 != 0 {
            take(raw, &mut pos, 2)?; // FHCRC
        }

        let mut out = Vec::new();
        loop {
            let first = take(raw, &mut pos, 1)?[0];
            if (first >> 1) & 0x03 != 0 {
                return Err(bad(
                    "compressed deflate blocks are not supported by the vendored \
                     flate2 shim (stored blocks only)",
                ));
            }
            let len_bytes = take(raw, &mut pos, 2)?;
            let len = u16::from_le_bytes([len_bytes[0], len_bytes[1]]);
            let nlen_bytes = take(raw, &mut pos, 2)?;
            let nlen = u16::from_le_bytes([nlen_bytes[0], nlen_bytes[1]]);
            if nlen != !len {
                return Err(bad("stored block length check failed"));
            }
            out.extend_from_slice(take(raw, &mut pos, len as usize)?);
            if first & 1 != 0 {
                break;
            }
        }

        let crc_bytes = take(raw, &mut pos, 4)?;
        let want_crc = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
        if crc32(&out) != want_crc {
            return Err(bad("crc mismatch"));
        }
        let size_bytes = take(raw, &mut pos, 4)?;
        let want_size =
            u32::from_le_bytes([size_bytes[0], size_bytes[1], size_bytes[2], size_bytes[3]]);
        if out.len() as u32 != want_size {
            return Err(bad("length mismatch"));
        }
        Ok(out)
    }

    impl<R: Read> Read for GzDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if let Some(mut inner) = self.inner.take() {
                let mut raw = Vec::new();
                inner.read_to_end(&mut raw)?;
                self.decoded = decode_all(&raw)?;
            }
            let n = buf.len().min(self.decoded.len() - self.pos);
            buf[..n].copy_from_slice(&self.decoded[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::read::GzDecoder;
    use super::write::GzEncoder;
    use super::*;

    fn round_trip(data: &[u8]) -> Vec<u8> {
        let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let stream = enc.finish().unwrap();
        let mut out = Vec::new();
        GzDecoder::new(&stream[..]).read_to_end(&mut out).unwrap();
        out
    }

    #[test]
    fn round_trips() {
        assert_eq!(round_trip(b""), b"");
        assert_eq!(round_trip(b"hello gzip\n"), b"hello gzip\n");
        let big: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        assert_eq!(round_trip(&big), big);
    }

    #[test]
    fn crc_corruption_detected() {
        let mut enc = GzEncoder::new(Vec::new(), Compression::default());
        enc.write_all(b"payload").unwrap();
        let mut stream = enc.finish().unwrap();
        let n = stream.len();
        stream[n - 6] ^= 0xFF; // flip a CRC byte
        let mut out = Vec::new();
        assert!(GzDecoder::new(&stream[..]).read_to_end(&mut out).is_err());
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926 (IEEE reflected).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn rejects_compressed_blocks() {
        // Header + a fixed-huffman block marker (BTYPE=01).
        let stream = [0x1F, 0x8B, 0x08, 0, 0, 0, 0, 0, 0, 0xFF, 0x03];
        let mut out = Vec::new();
        assert!(GzDecoder::new(&stream[..]).read_to_end(&mut out).is_err());
    }
}
