//! Vendored stub of the `xla` (PJRT) crate API surface.
//!
//! The offline build environment ships no PJRT plugin or XLA runtime, so
//! this crate provides the exact types and method signatures
//! `runtime/client.rs` compiles against, with [`PjRtClient::cpu`] failing
//! at construction time. Everything downstream of the coordinator already
//! treats "semantic runtime unavailable" as a recoverable condition
//! (semantic metrics report an explanatory error; the runtime round-trip
//! tests skip when artifacts are missing), so the stub degrades the
//! feature instead of the build.

use std::fmt;

/// XLA error type (implements `std::error::Error` so callers can wrap it
/// with `anyhow::Context`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: this build vendors an xla stub (no native XLA plugin in the \
         offline toolchain); semantic metrics and the device bootstrap are disabled"
            .to_string(),
    ))
}

/// Element types transferable to device buffers.
pub trait NativeType: Copy + Default + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Parsed HLO module text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// A computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host-side literal (tensor) value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. Construction always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn error_is_std_error() {
        fn takes_std_error(_e: &dyn std::error::Error) {}
        takes_std_error(&Error("x".into()));
    }
}
