//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **batch size** — Pandas-UDF batch granularity vs throughput;
//! 2. **executor concurrency** — in-flight requests per executor (the
//!    knob behind the Figure 2 linear-region slope);
//! 3. **adaptive vs static rate shares under partition skew** — the §6.1
//!    limitation the adaptive extension addresses;
//! 4. **token-bucket initial fill** — burst behaviour at job start;
//! 5. **cache flush threshold** — write batching vs Delta version
//!    count.

use spark_llm_eval::cache::ResponseCache;
use spark_llm_eval::config::CachePolicy;
use spark_llm_eval::providers::InferenceResponse;
use spark_llm_eval::report::table;
use spark_llm_eval::sim::{simulate, SimParams};
use spark_llm_eval::util::bench::section;

fn main() {
    section("ablation 1 — batch size (8 executors, 20k examples)");
    let mut rows = Vec::new();
    for batch in [1usize, 10, 50, 200, 1000] {
        let p = SimParams { batch_size: batch, n_examples: 20_000, ..Default::default() };
        let out = simulate(&p, None);
        rows.push(vec![batch.to_string(), format!("{:.0}", out.throughput_per_min)]);
    }
    println!("{}", table(&["batch_size", "examples/min"], &rows));

    section("ablation 2 — per-executor concurrency");
    let mut rows = Vec::new();
    for conc in [1usize, 2, 4, 8, 16, 32] {
        let p = SimParams { concurrency: conc, executors: 4, n_examples: 20_000, ..Default::default() };
        let out = simulate(&p, None);
        rows.push(vec![
            conc.to_string(),
            format!("{:.0}", out.throughput_per_min),
            format!("{:.0}%", out.rate_wait_frac * 100.0),
        ]);
    }
    println!("{}", table(&["concurrency", "examples/min", "rate-limited"], &rows));

    section("ablation 3 — adaptive vs static shares under partition skew");
    let mut rows = Vec::new();
    for skew in [0.5, 0.65, 0.8, 0.95] {
        let base = SimParams {
            executors: 8,
            n_examples: 60_000,
            skew,
            global_rpm: 6_000.0,
            ..Default::default()
        };
        let stat = simulate(&base, None);
        let adap = simulate(&SimParams { adaptive_shares: true, ..base }, None);
        rows.push(vec![
            format!("{skew:.2}"),
            format!("{:.1}s", stat.total_secs),
            format!("{:.1}s", adap.total_secs),
            format!("{:.1}%", 100.0 * (1.0 - adap.total_secs / stat.total_secs)),
        ]);
    }
    println!(
        "{}",
        table(&["skew", "static makespan", "adaptive makespan", "adaptive gain"], &rows)
    );

    section("ablation 4 — bucket initial fill (burst at job start)");
    use spark_llm_eval::ratelimit::{Clock, TokenBucket, VirtualClock};
    let mut rows = Vec::new();
    for fill in [0.0, 1.0 / 60.0, 0.25, 1.0] {
        let clock = VirtualClock::new();
        let mut bucket = TokenBucket::with_fill(600.0, 1e12, fill, clock.as_ref());
        let mut admitted_first_10s = 0;
        while clock.now() < 10.0 {
            bucket.acquire(1.0, clock.as_ref());
            admitted_first_10s += 1;
        }
        rows.push(vec![format!("{fill:.3}"), admitted_first_10s.to_string()]);
    }
    println!("{}", table(&["initial fill", "admits in first 10 s (rpm=600)"], &rows));

    section("ablation 5 — cache flush threshold (write batching)");
    let mut rows = Vec::new();
    for flush_every in [10usize, 100, 1000] {
        let dir = std::env::temp_dir().join(format!(
            "slleval-ablate-cache-{}-{flush_every}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.flush_every = flush_every;
        let t0 = std::time::Instant::now();
        for i in 0..2_000 {
            let resp = InferenceResponse {
                text: format!("response {i}"),
                input_tokens: 100,
                output_tokens: 50,
                latency_ms: 1.0,
                cost_usd: 0.0,
            };
            cache.put(&format!("prompt {i}"), "m", "p", 0.0, 100, &resp).unwrap();
        }
        cache.flush().unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let versions = cache.current_version().unwrap().unwrap() + 1;
        rows.push(vec![
            flush_every.to_string(),
            format!("{elapsed:.2}s"),
            versions.to_string(),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }
    println!(
        "{}",
        table(&["flush_every", "2k puts wall time", "table versions"], &rows)
    );
}
