//! Figure 2 reproduction: throughput vs executor count, with the
//! sequential baseline of §5.2 (paper: linear to ~8 executors, plateau
//! ~9,800/min, single executor ~1,200/min, sequential 450/min, 21×
//! speedup at 8 executors).

use spark_llm_eval::report::tables::figure2;
use spark_llm_eval::sim::{simulate, SimParams};
use spark_llm_eval::util::bench::{bench, section};

fn main() {
    section("Figure 2 — throughput scaling with executor count");
    let (rows, text) = figure2(10_000);
    println!("{text}");

    // Shape assertions (who wins / where the knee falls).
    let t1 = rows.iter().find(|r| r.executors == 1).unwrap().mean_throughput;
    let t8 = rows.iter().find(|r| r.executors == 8).unwrap().mean_throughput;
    let t16 = rows.iter().find(|r| r.executors == 16).unwrap().mean_throughput;
    println!("shape checks:");
    println!("  1 executor  = {t1:.0}/min (paper ~1,200)");
    println!("  8 executors = {t8:.0}/min (paper ~9,800)");
    println!("  8→16 gain   = {:.2}x (saturation; paper: plateau at ~8)", t16 / t8);
    assert!(t8 / t1 > 5.0, "should scale substantially to 8 executors");
    assert!(t16 / t8 < 1.35, "rate limit must cap scaling past the knee");

    section("DES micro-benchmark (cost of one sweep point)");
    bench("simulate(8 executors, 10k examples)", 200.0, || {
        let p = SimParams { executors: 8, n_examples: 10_000, ..Default::default() };
        std::hint::black_box(simulate(&p, None));
    });
}
