//! Table 5 reproduction: empirical coverage of 95% confidence intervals
//! on lognormal(σ=0.5) data (paper: BCa ≈ nominal even at n=50;
//! percentile and analytical undercover for skewed data at small n).

use spark_llm_eval::report::tables::table5;
use spark_llm_eval::util::bench::{bench, section};

fn main() {
    section("Table 5 — empirical coverage of 95% CIs");
    // Full paper protocol: 1,000 datasets per cell, B=1000 resamples.
    let (rows, text) = table5(1000, 1000);
    println!("{text}");

    println!("shape checks (paper: 91.2/94.3/88.7 at n=50 → 94.6/95.1/94.2 at n=1000):");
    let pct = &rows[0];
    let bca = &rows[1];
    let t = &rows[2];
    println!(
        "  n=50:  percentile {:.1}%, BCa {:.1}%, t {:.1}%",
        pct.coverage[0] * 100.0,
        bca.coverage[0] * 100.0,
        t.coverage[0] * 100.0
    );
    // BCa must dominate at small n, all near nominal at n=1000.
    assert!(bca.coverage[0] >= pct.coverage[0] - 0.005, "BCa >= percentile at n=50");
    assert!(bca.coverage[0] >= t.coverage[0] - 0.005, "BCa >= t at n=50");
    for r in &rows {
        assert!(r.coverage[2] > 0.925, "{} at n=1000: {:.3}", r.method, r.coverage[2]);
    }

    section("bootstrap micro-benchmarks");
    use spark_llm_eval::stats::bootstrap::{bootstrap_means, bootstrap_statistics};
    use spark_llm_eval::stats::describe::mean;
    use spark_llm_eval::util::rng::Rng;
    let mut rng = Rng::new(1);
    let values: Vec<f64> = (0..1000).map(|_| rng.lognormal(0.0, 0.5)).collect();
    bench("bootstrap_means      (n=1000, B=1000)", 300.0, || {
        let mut r = Rng::new(2);
        std::hint::black_box(bootstrap_means(&values, 1000, &mut r));
    });
    bench("bootstrap_statistics (n=1000, B=1000, mean closure)", 300.0, || {
        let mut r = Rng::new(2);
        std::hint::black_box(bootstrap_statistics(&values, &mean, 1000, &mut r));
    });
}
