//! Remote-transport benchmark: the TCP executor backend on loopback
//! against the in-process thread backend, isolating what the wire
//! (frames, sockets, heartbeats, spill upload) costs per row.
//!
//! The serve loop runs *in-process* ([`serve_connection`] on an
//! accept-loop thread) so the numbers measure the transport, not child
//! process spawning. Three questions, three scenario groups:
//!
//! 1. pure transport overhead per row (no provider latency, batch 10);
//! 2. how frame-heavy small batches (batch 2 — 5x the task round-trips)
//!    degrade that overhead;
//! 3. whether the overhead disappears behind a realistic injected
//!    provider-latency profile (sleep_latency with a scaled-down
//!    Table 3 profile), which is the regime real runs live in.
//!
//! Results are recorded in `BENCH_remote.json` at the repository root.
//! Identity (same metric values as the thread backend) is asserted on
//! every remote run — a fast transport that changes answers is wrong.

use std::time::Instant;

use spark_llm_eval::config::{BackendKind, CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::{serve_connection, EvalRunner};
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::util::bench::section;
use spark_llm_eval::util::json::Json;

const EXECUTORS: usize = 4;
const ROWS: usize = 600;
const LATENCY_ROWS: usize = 200;
const REPS: usize = 3;

/// An in-process `serve-worker`: accept loop on a loopback listener,
/// one [`serve_connection`] session thread per accepted executor
/// socket. The thread is leaked — it lives for the whole bench run.
fn spawn_loopback_host() -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("binding loopback host");
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { continue };
            std::thread::spawn(move || {
                let _ = serve_connection(stream);
            });
        }
    });
    addr
}

fn runner(latency: bool) -> EvalRunner {
    // With latency injection the driver must ride a real clock (the
    // serve sessions sleep on theirs); otherwise virtual + no sleeps.
    let mut r =
        if latency { EvalRunner::new() } else { EvalRunner::with_clock(VirtualClock::new()) };
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        // 5% of the Table 3-calibrated profile keeps the bench short
        // while still dwarfing per-frame transport costs.
        latency_scale: if latency { 0.05 } else { 1.0 },
        sleep_latency: latency,
        ..Default::default()
    };
    r
}

fn task(backend: BackendKind, hosts: Vec<String>, batch_size: usize) -> EvalTask {
    let mut task = EvalTask::default();
    task.executors = EXECUTORS;
    task.backend = backend;
    task.hosts = hosts;
    task.inference.batch_size = batch_size;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    task
}

/// Best-of-REPS wall time for one configuration; returns the last run's
/// exact_match value for the identity check.
fn measure(
    df: &spark_llm_eval::data::DataFrame,
    t: &EvalTask,
    latency: bool,
    reps: usize,
) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut value = f64::NAN;
    for _ in 0..reps {
        let clock = Instant::now();
        let result = runner(latency).evaluate(df, t).expect("bench run");
        best = best.min(clock.elapsed().as_secs_f64());
        value = result.metric("exact_match").unwrap().value;
        assert_eq!(result.inference.sched.executor_deaths, 0, "healthy loopback run");
    }
    (best, value)
}

fn main() {
    let parallelism = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    section(&format!(
        "remote transport benchmark — {ROWS} rows, {EXECUTORS} executors (loopback), \
         {parallelism} cores"
    ));
    let host = spawn_loopback_host();
    let df = synth::generate_default(ROWS, 91);

    // 1 + 2: pure transport overhead, normal and frame-heavy batches.
    let (t_thread, v_thread) =
        measure(&df, &task(BackendKind::Thread, Vec::new(), 10), false, REPS);
    let (t_remote, v_remote) =
        measure(&df, &task(BackendKind::Remote, vec![host.clone()], 10), false, REPS);
    let (t_remote_small, v_small) =
        measure(&df, &task(BackendKind::Remote, vec![host.clone()], 2), false, REPS);
    assert_eq!(v_remote, v_thread, "remote must be answer-identical to thread");
    assert_eq!(v_small, v_thread, "batch size must not change answers");

    let overhead_us = (t_remote - t_thread).max(0.0) / ROWS as f64 * 1e6;
    let overhead_small_us = (t_remote_small - t_thread).max(0.0) / ROWS as f64 * 1e6;
    println!(
        "no-latency: thread {:>7.1}ms | remote {:>7.1}ms ({overhead_us:.0}µs/row) | \
         remote batch=2 {:>7.1}ms ({overhead_small_us:.0}µs/row)",
        t_thread * 1e3,
        t_remote * 1e3,
        t_remote_small * 1e3,
    );

    // 3: the same comparison under an injected provider-latency profile.
    section(&format!(
        "injected latency profile — {LATENCY_ROWS} rows, latency_scale 0.05, sleeps on"
    ));
    let df_lat = synth::generate_default(LATENCY_ROWS, 92);
    let (t_thread_lat, v_thread_lat) =
        measure(&df_lat, &task(BackendKind::Thread, Vec::new(), 10), true, 1);
    let (t_remote_lat, v_remote_lat) =
        measure(&df_lat, &task(BackendKind::Remote, vec![host.clone()], 10), true, 1);
    assert_eq!(v_remote_lat, v_thread_lat, "identity must hold under latency too");
    let lat_overhead_frac = (t_remote_lat - t_thread_lat).max(0.0) / t_thread_lat;
    println!(
        "with latency: thread {:>7.1}ms | remote {:>7.1}ms (+{:.1}%)",
        t_thread_lat * 1e3,
        t_remote_lat * 1e3,
        lat_overhead_frac * 100.0,
    );

    let report = Json::obj(vec![
        ("benchmark", Json::str("bench_remote")),
        ("rows", Json::num(ROWS as f64)),
        ("executors", Json::num(EXECUTORS as f64)),
        ("reps", Json::num(REPS as f64)),
        ("host_parallelism", Json::num(parallelism as f64)),
        ("thread_secs", Json::num(t_thread)),
        ("remote_secs", Json::num(t_remote)),
        ("remote_small_batch_secs", Json::num(t_remote_small)),
        ("transport_overhead_us_per_row", Json::num(overhead_us)),
        ("transport_overhead_us_per_row_batch2", Json::num(overhead_small_us)),
        ("latency_rows", Json::num(LATENCY_ROWS as f64)),
        ("latency_thread_secs", Json::num(t_thread_lat)),
        ("latency_remote_secs", Json::num(t_remote_lat)),
        ("latency_overhead_frac", Json::num(lat_overhead_frac)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_remote.json");
    std::fs::write(&out_path, report.to_pretty()).expect("writing BENCH_remote.json");
    println!("\nresults written to {}", out_path.display());
}
