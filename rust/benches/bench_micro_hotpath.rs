//! Hot-path micro-benchmarks across all three layers, used by the
//! EXPERIMENTS.md §Perf iteration log:
//!
//! - L3: token bucket, lexical metrics, end-to-end pipeline throughput
//!   (virtual clock — measures coordinator overhead, not sleeps);
//! - L2/L1 via the PJRT runtime: embedder batch, BERTScore batch
//!   (Pallas kernel path), device bootstrap.

use spark_llm_eval::config::{EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::metrics::lexical;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::{TokenBucket, VirtualClock};
use spark_llm_eval::runtime::{default_artifact_dir, SemanticRuntime};
use spark_llm_eval::util::bench::{bench, section};
use spark_llm_eval::util::rng::Rng;

fn main() {
    section("L3 — rate limiter");
    let clock = VirtualClock::new();
    let mut bucket = TokenBucket::new(1e9, 1e12, clock.as_ref());
    bench("token_bucket.acquire (uncontended)", 50.0, || {
        std::hint::black_box(bucket.acquire(500.0, clock.as_ref()));
    });

    section("L3 — lexical metric kernels");
    let cand = "the quick brown fox jumps over the lazy dog near the river bank today";
    let reference = "a quick brown fox jumped over a lazy dog by the river bank yesterday";
    bench("exact_match + normalize", 50.0, || {
        std::hint::black_box(lexical::exact_match(cand, reference, lexical::Normalize::default()));
    });
    bench("token_f1", 50.0, || {
        std::hint::black_box(lexical::token_f1(cand, reference));
    });
    bench("bleu (4-gram)", 50.0, || {
        std::hint::black_box(lexical::bleu(cand, reference));
    });
    bench("rouge_l (LCS)", 50.0, || {
        std::hint::black_box(lexical::rouge_l(cand, reference));
    });

    section("L3 — end-to-end pipeline (virtual clock, no sleeps)");
    let df = synth::generate_default(2_000, 1);
    let mk_runner = || {
        let mut r = EvalRunner::with_clock(VirtualClock::new());
        r.service_config = SimServiceConfig {
            server_error_rate: 0.0,
            unparseable_rate: 0.0,
            sleep_latency: false,
            ..Default::default()
        };
        r
    };
    let mut task = EvalTask::default();
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];
    let runner = mk_runner();
    let r = bench("evaluate 2k examples (2 lexical metrics, 8 exec)", 2_000.0, || {
        std::hint::black_box(runner.evaluate(&df, &task).unwrap());
    });
    println!(
        "  -> coordinator throughput {:.0} examples/s (pipeline overhead only)",
        r.throughput(2_000.0)
    );

    section("L2/L1 — PJRT artifacts (SimLM encoder + Pallas BERTScore)");
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` for the L1/L2 benches");
        return;
    }
    let rt = SemanticRuntime::load(&dir).unwrap();
    let m = &rt.manifest.model;
    let texts: Vec<String> = (0..m.batch)
        .map(|i| format!("sample sentence number {i} about rate limits and caching"))
        .collect();
    let text_refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let r = bench(
        &format!("embed_texts (batch {}, seq {}, d {})", m.batch, m.max_seq, m.d_model),
        2_000.0,
        || {
            std::hint::black_box(rt.embed_texts(&text_refs).unwrap());
        },
    );
    println!("  -> {:.0} texts/s", r.throughput(m.batch as f64));

    let pairs: Vec<(&str, &str)> = texts
        .iter()
        .map(|t| (t.as_str(), "reference answer about caching limits"))
        .collect();
    let r = bench(
        &format!("bertscore_texts (batch {}, Pallas kernel)", m.batch),
        2_000.0,
        || {
            std::hint::black_box(rt.bertscore_texts(&pairs).unwrap());
        },
    );
    println!("  -> {:.0} pairs/s", r.throughput(m.batch as f64));

    let mut rng = Rng::new(5);
    let values: Vec<f64> = (0..512).map(|_| rng.f64()).collect();
    let r = bench("device bootstrap (n=512, B=1000, XLA gather)", 2_000.0, || {
        let mut r = Rng::new(6);
        std::hint::black_box(rt.bootstrap_means(&values, &mut r).unwrap());
    });
    println!("  -> {:.0} resample-means/s", r.throughput(1_000.0));
    // Native comparison.
    let rn = bench("native bootstrap (n=512, B=1000, rust)", 2_000.0, || {
        let mut r = Rng::new(6);
        std::hint::black_box(spark_llm_eval::stats::bootstrap::bootstrap_means(
            &values, 1_000, &mut r,
        ));
    });
    println!(
        "  -> device/native ratio: {:.2}x",
        r.mean_ns / rn.mean_ns
    );
}
