//! Table 3 reproduction: throughput by dataset size at 8 executors
//! (paper: 7,200/min @1k → 9,800/min @100k; p50 320→360 ms;
//! scheduling overhead negligible above 10k examples).

use spark_llm_eval::report::tables::table3;
use spark_llm_eval::util::bench::section;

fn main() {
    section("Table 3 — throughput by dataset size (8 executors)");
    let (rows, text) = table3();
    println!("{text}");

    println!("shape checks:");
    let small = &rows[0];
    let large = &rows[3];
    println!(
        "  1k vs 100k throughput: {:.0} vs {:.0} ({:.0}% overhead at 1k; paper: 7200 vs 9800)",
        small.throughput,
        large.throughput,
        100.0 * (1.0 - small.throughput / large.throughput)
    );
    println!(
        "  p99/p50 tail ratio: {:.2} (paper: ~2.8)",
        large.p99_ms / large.p50_ms
    );
    assert!(small.throughput < large.throughput);
    assert!(rows.windows(2).all(|w| w[0].throughput <= w[1].throughput * 1.02));
}
