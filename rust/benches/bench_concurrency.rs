//! In-executor concurrency benchmark (ISSUE 4): simulated live-mode
//! inference under the virtual clock, sweeping `inference.concurrency`.
//!
//! The virtual clock makes provider latency *slept* rather than skipped
//! (`sleep_latency: true`), so a latency-bound run's virtual wall time is
//! the quantity the pipelined client exists to shrink: at concurrency 1
//! each executor pays every round trip sequentially; at concurrency N the
//! completion-queue client overlaps N in-flight requests, and virtual
//! wall time drops ~N×. Metric values, CIs, and cost accounting must not
//! move — the pipeline changes *when* requests are in flight, never what
//! they return or cost. Results land in `BENCH_concurrency.json` at the
//! repository root.

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::util::bench::section;
use spark_llm_eval::util::json::Json;

const N: usize = 240;
const SEED: u64 = 11;
const LEVELS: [usize; 4] = [1, 2, 4, 8];

fn task(concurrency: usize, executors: usize) -> EvalTask {
    let mut task = EvalTask::default();
    task.task_id = format!("bench-concurrency-{concurrency}x{executors}");
    task.executors = executors;
    task.inference.concurrency = concurrency;
    task.inference.cache_policy = CachePolicy::Disabled;
    // Keep the schedule deterministic: no speculation/splitting noise.
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    task
}

/// One virtual-clock live-mode run; returns (virtual wall secs of the
/// inference stage, throughput/min, metric value, ci, cost, peak in-flight).
fn run(concurrency: usize, executors: usize) -> (f64, f64, f64, (f64, f64), f64, usize) {
    let clock = VirtualClock::new();
    let mut runner = EvalRunner::with_clock(clock);
    // Latency is slept on the virtual clock; faults off so every level
    // sees the identical workload.
    runner.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: true,
        ..Default::default()
    };
    let df = synth::generate_default(N, SEED);
    let result = runner.evaluate(&df, &task(concurrency, executors)).unwrap();
    let m = &result.metrics[0];
    (
        result.inference.wall_secs,
        result.inference.throughput_per_min,
        m.value,
        (m.ci.lo, m.ci.hi),
        result.inference.total_cost_usd,
        result.inference.peak_in_flight,
    )
}

fn main() {
    section(&format!(
        "in-executor concurrency — {N} examples, virtual clock, latency slept"
    ));

    let mut rows = Vec::new();
    let mut by_level = Vec::new();
    for executors in [1usize, 4] {
        let mut base_wall = 0.0;
        for &concurrency in &LEVELS {
            let (wall, tp, value, ci, cost, peak) = run(concurrency, executors);
            if concurrency == 1 {
                base_wall = wall;
            }
            let speedup = base_wall / wall;
            println!(
                "executors {executors} × concurrency {concurrency}: wall {wall:>8.1}s \
                 ({speedup:.2}x) | {tp:>7.0}/min | peak in-flight {peak} | \
                 exact_match {value:.4} | cost ${cost:.2}",
            );
            rows.push((executors, concurrency, wall, speedup, tp, value, ci, cost, peak));
            by_level.push(Json::obj(vec![
                ("executors", Json::num(executors as f64)),
                ("concurrency", Json::num(concurrency as f64)),
                ("virtual_wall_secs", Json::num(wall)),
                ("speedup_vs_concurrency_1", Json::num(speedup)),
                ("throughput_per_min", Json::num(tp)),
                ("peak_in_flight", Json::num(peak as f64)),
                ("exact_match", Json::num(value)),
                ("ci_lower", Json::num(ci.0)),
                ("ci_upper", Json::num(ci.1)),
                ("cost_usd", Json::num(cost)),
            ]));
        }
    }

    // Invariance gates: concurrency may only change the schedule. Metric
    // values, CIs, and cost must be identical at every level.
    for chunk in rows.chunks(LEVELS.len()) {
        let (_, _, _, _, _, v0, ci0, cost0, _) = chunk[0];
        for &(executors, concurrency, _, _, _, v, ci, cost, peak) in chunk {
            assert_eq!(v, v0, "metric moved at executors {executors} concurrency {concurrency}");
            assert_eq!(ci, ci0, "CI moved at executors {executors} concurrency {concurrency}");
            assert!(
                (cost - cost0).abs() < 1e-9,
                "cost moved at executors {executors} concurrency {concurrency}"
            );
            assert!(
                peak <= concurrency,
                "peak in-flight {peak} exceeds configured concurrency {concurrency}"
            );
        }
    }

    // Acceptance gate (ISSUE 4): ≥ 4× virtual-wall speedup at
    // concurrency 8 on a latency-bound run.
    for chunk in rows.chunks(LEVELS.len()) {
        let (executors, _, _, _, _, _, _, _, _) = chunk[0];
        let speedup8 = chunk[LEVELS.len() - 1].3;
        assert!(
            speedup8 >= 4.0,
            "concurrency 8 must cut latency-bound virtual wall time ≥ 4x \
             (executors {executors}: got {speedup8:.2}x)"
        );
    }

    let report = Json::obj(vec![
        ("benchmark", Json::str("bench_concurrency")),
        ("examples", Json::num(N as f64)),
        ("seed", Json::num(SEED as f64)),
        ("clock", Json::str("virtual (latency slept)")),
        ("levels", Json::arr(by_level)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_concurrency.json");
    std::fs::write(&out_path, report.to_pretty()).expect("writing BENCH_concurrency.json");
    println!("\nresults written to {}", out_path.display());
}
