//! Straggler benchmark: static range partitioning vs work stealing vs
//! work stealing + speculation + adaptive splitting, under injected
//! heavy-tailed (pareto) per-row latency and a slow-node scenario.
//!
//! This is the scheduler's reason to exist (ISSUE 1 / paper §6.1): with
//! static partitioning one hot partition sets the makespan; dynamic task
//! scheduling keeps every executor busy. Results are recorded in
//! `BENCH_sched.json` at the repository root.

use spark_llm_eval::data::{DataFrame, Value};
use spark_llm_eval::sched::{run_scheduled, SchedOutput, SchedulerConfig};
use spark_llm_eval::util::bench::section;
use spark_llm_eval::util::json::Json;
use spark_llm_eval::util::rng::Rng;
use std::time::Instant;

const EXECUTORS: usize = 8;
const BATCH: usize = 10;
const ROWS: usize = 1600;
const BASE_US: u64 = 80;
const REPS: usize = 3;

/// Busy-wait for `us` microseconds (thread::sleep is too coarse).
fn spin(us: u64) {
    let t = Instant::now();
    while (t.elapsed().as_micros() as u64) < us {
        std::hint::spin_loop();
    }
}

/// Per-row cost in microseconds for each scenario.
struct Scenario {
    name: &'static str,
    description: &'static str,
    /// cost(row) in µs.
    cost: Box<dyn Fn(usize) -> u64 + Sync>,
    /// Extra multiplier for executor 0 (slow-node scenario).
    slow_node_mult: u64,
}

fn scenarios() -> Vec<Scenario> {
    // Deterministic pareto draws per row: x = u^(-1/alpha), alpha = 1.1,
    // capped. Heavy tail: a few rows cost ~40x the base.
    let mut rng = Rng::new(0x5EED);
    let pareto: Vec<u64> = (0..ROWS)
        .map(|_| {
            let u = (1.0 - rng.f64()).max(1e-9);
            let x = u.powf(-1.0 / 1.1);
            (BASE_US as f64 * x.min(40.0)) as u64
        })
        .collect();

    vec![
        Scenario {
            name: "clustered_hot_partition",
            description: "first eighth of the rows is 20x slower (hot partition)",
            cost: Box::new(|row| {
                if row < ROWS / EXECUTORS {
                    BASE_US * 20
                } else {
                    BASE_US
                }
            }),
            slow_node_mult: 1,
        },
        Scenario {
            name: "pareto_tail",
            description: "iid pareto(1.1) per-row cost, capped at 40x",
            cost: Box::new(move |row| pareto[row]),
            slow_node_mult: 1,
        },
        Scenario {
            name: "slow_node",
            description: "uniform rows but executor 0 runs 8x slower",
            cost: Box::new(|_| BASE_US),
            slow_node_mult: 8,
        },
    ]
}

fn frame() -> DataFrame {
    DataFrame::from_columns(vec![(
        "x",
        (0..ROWS as i64).map(Value::Int).collect::<Vec<_>>(),
    )])
    .unwrap()
}

/// Run one configuration over a scenario; returns (best seconds, output of
/// the last rep for telemetry).
fn measure(df: &DataFrame, sc: &Scenario, cfg: &SchedulerConfig) -> (f64, SchedOutput<i64>) {
    let mut best = f64::INFINITY;
    let mut last = None;
    for _ in 0..REPS {
        let t = Instant::now();
        let out = run_scheduled(
            df,
            EXECUTORS,
            BATCH,
            cfg,
            None,
            |eid| Ok(if eid == 0 { sc.slow_node_mult } else { 1 }),
            |mult, df, slice| {
                let mut out = Vec::with_capacity(slice.len());
                for i in slice.indices() {
                    spin((sc.cost)(i) * *mult);
                    out.push(df.row(i).get("x").unwrap().as_f64().unwrap() as i64);
                }
                Ok(out)
            },
        )
        .unwrap();
        assert_eq!(out.rows.len(), ROWS, "row conservation");
        assert!(out.rows.iter().enumerate().all(|(i, &v)| v == i as i64), "row order");
        best = best.min(t.elapsed().as_secs_f64());
        last = Some(out);
    }
    (best, last.unwrap())
}

fn main() {
    let parallelism = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    section(&format!(
        "scheduler skew benchmark — {ROWS} rows, {EXECUTORS} executors, {parallelism} cores"
    ));

    let df = frame();
    let static_cfg = SchedulerConfig::legacy();
    let ws_cfg = SchedulerConfig {
        speculation: false,
        adaptive_split: false,
        ..Default::default()
    };
    let full_cfg = SchedulerConfig::default();

    let mut scenario_jsons = Vec::new();
    for sc in scenarios() {
        section(&format!("{} — {}", sc.name, sc.description));
        let (t_static, _) = measure(&df, &sc, &static_cfg);
        let (t_ws, ws_out) = measure(&df, &sc, &ws_cfg);
        let (t_full, full_out) = measure(&df, &sc, &full_cfg);
        let speedup_ws = t_static / t_ws;
        let speedup_full = t_static / t_full;
        println!(
            "static {:>8.1}ms | stealing {:>8.1}ms ({speedup_ws:.2}x) | \
             +speculation+split {:>8.1}ms ({speedup_full:.2}x)",
            t_static * 1e3,
            t_ws * 1e3,
            t_full * 1e3,
        );
        println!(
            "telemetry (full): {} tasks, {} steals, {} speculative ({} won), {} splits, \
             skew {:.2}x",
            full_out.sched.tasks,
            full_out.sched.steals,
            full_out.sched.speculative_launched,
            full_out.sched.speculative_wins,
            full_out.sched.splits,
            full_out.sched.skew_ratio,
        );
        scenario_jsons.push(Json::obj(vec![
            ("name", Json::str(sc.name)),
            ("description", Json::str(sc.description)),
            ("rows", Json::num(ROWS as f64)),
            ("executors", Json::num(EXECUTORS as f64)),
            ("static_secs", Json::num(t_static)),
            ("work_stealing_secs", Json::num(t_ws)),
            ("ws_speculation_split_secs", Json::num(t_full)),
            ("speedup_ws_vs_static", Json::num(speedup_ws)),
            ("speedup_full_vs_static", Json::num(speedup_full)),
            ("steals", Json::num(ws_out.sched.steals as f64)),
            ("full_telemetry", full_out.sched.to_json()),
        ]));

        // Acceptance gate (ISSUE 1): ≥1.5x over static partitioning under
        // latency skew — only meaningful with real parallelism available.
        if parallelism >= 4 && sc.name == "clustered_hot_partition" {
            assert!(
                speedup_ws.max(speedup_full) >= 1.5,
                "work stealing must beat static partitioning by ≥1.5x under clustered skew \
                 (got ws {speedup_ws:.2}x, full {speedup_full:.2}x)"
            );
        }
    }

    let report = Json::obj(vec![
        ("benchmark", Json::str("bench_sched_skew")),
        ("rows", Json::num(ROWS as f64)),
        ("executors", Json::num(EXECUTORS as f64)),
        ("batch_size", Json::num(BATCH as f64)),
        ("reps", Json::num(REPS as f64)),
        ("host_parallelism", Json::num(parallelism as f64)),
        ("scenarios", Json::arr(scenario_jsons)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_sched.json");
    std::fs::write(&out_path, report.to_pretty()).expect("writing BENCH_sched.json");
    println!("\nresults written to {}", out_path.display());
}
