//! Wall-clock companion to `bench_concurrency` (ROADMAP "real-clock
//! pipelined throughput measurement"): the virtual-clock bench proves the
//! ~N× latency overlap; this one runs *real* sleeps at scaled-down
//! latencies on the wall clock, which is the only way to see the two
//! costs the virtual clock hides:
//!
//! - **scoped-thread overhead per batch** — the pipelined client spawns
//!   `concurrency` slot threads per batch via `std::thread::scope`, paid
//!   in real microseconds;
//! - **the slot-count crossover** — the concurrency level where adding
//!   slots stops buying wall time (the remaining per-batch serial work
//!   dominates the remaining latency overlap).
//!
//! Latencies are scaled down ~25× (p50 ≈ 13 ms instead of 320 ms) so the
//! whole sweep stays under ~10 s while keeping sleeps long enough to
//! dominate scheduling noise at low concurrency. Results land in
//! `BENCH_concurrency_wall.json` at the repository root.

use spark_llm_eval::config::{CachePolicy, EvalTask, MetricConfig};
use spark_llm_eval::coordinator::EvalRunner;
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::util::bench::section;
use spark_llm_eval::util::json::Json;

const N: usize = 96;
const SEED: u64 = 17;
const BATCH: usize = 16;
const LATENCY_SCALE: f64 = 0.04; // 320 ms p50 -> ~12.8 ms
const LEVELS: [usize; 6] = [1, 2, 4, 8, 16, 32];

fn task(concurrency: usize) -> EvalTask {
    let mut task = EvalTask::default();
    task.task_id = format!("bench-concurrency-wall-{concurrency}");
    task.executors = 1; // isolate the per-executor pipeline
    task.inference.batch_size = BATCH;
    task.inference.concurrency = concurrency;
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.metrics = vec![MetricConfig::new("exact_match", "lexical")];
    task
}

/// One real-clock run; returns (wall secs of the inference stage,
/// metric value, cost).
fn run(concurrency: usize) -> (f64, f64, f64) {
    // Real clock, real (scaled) sleeps, faults off so every level sees
    // the identical workload.
    let mut runner = EvalRunner::new();
    runner.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: true,
        latency_scale: LATENCY_SCALE,
        ..Default::default()
    };
    let df = synth::generate_default(N, SEED);
    let result = runner.evaluate(&df, &task(concurrency)).unwrap();
    (
        result.inference.wall_secs,
        result.metrics[0].value,
        result.inference.total_cost_usd,
    )
}

fn main() {
    section(&format!(
        "wall-clock in-executor concurrency — {N} examples, 1 executor, \
         latency ×{LATENCY_SCALE} (real sleeps)"
    ));

    let batches = N.div_ceil(BATCH) as f64;
    let mut rows: Vec<(usize, f64, f64)> = Vec::new(); // (level, wall, speedup)
    let mut by_level = Vec::new();
    let (mut base_wall, mut base_value, mut base_cost) = (0.0, 0.0, 0.0);
    for &concurrency in &LEVELS {
        let (wall, value, cost) = run(concurrency);
        if concurrency == 1 {
            (base_wall, base_value, base_cost) = (wall, value, cost);
        }
        let speedup = base_wall / wall;
        // The latency-bound floor at this level is ~wall(1)/c; anything
        // above it is serial per-batch work + scoped-thread overhead.
        let ideal = base_wall / concurrency as f64;
        let overhead_ms_per_batch = ((wall - ideal) / batches * 1e3).max(0.0);
        println!(
            "concurrency {concurrency:>2}: wall {wall:>7.3}s ({speedup:>5.2}x) | \
             overhead ≈ {overhead_ms_per_batch:>6.2} ms/batch | exact_match {value:.4}",
        );
        assert_eq!(value, base_value, "metric moved at concurrency {concurrency}");
        assert!(
            (cost - base_cost).abs() < 1e-9,
            "cost moved at concurrency {concurrency}"
        );
        rows.push((concurrency, wall, speedup));
        by_level.push(Json::obj(vec![
            ("concurrency", Json::num(concurrency as f64)),
            ("wall_secs", Json::num(wall)),
            ("speedup_vs_concurrency_1", Json::num(speedup)),
            ("ideal_wall_secs", Json::num(ideal)),
            ("overhead_ms_per_batch", Json::num(overhead_ms_per_batch)),
            ("exact_match", Json::num(value)),
            ("cost_usd", Json::num(cost)),
        ]));
    }

    // Crossover: the first level whose wall time is not at least 10%
    // better than the previous level's — past it, extra slots no longer
    // pay for their scheduling overhead at this latency scale.
    let mut crossover = *LEVELS.last().unwrap();
    for w in rows.windows(2) {
        let (_, prev_wall, _) = w[0];
        let (level, wall, _) = w[1];
        if wall > prev_wall * 0.90 {
            crossover = level;
            break;
        }
    }
    println!("\nslot-count crossover (marginal gain < 10%): concurrency {crossover}");

    // Soft acceptance on real hardware: latency-bound at ~13 ms/request,
    // 4 slots must cut wall time well past scheduling noise. (The strict
    // ≥4× @ 8 gate lives in the deterministic virtual-clock bench.)
    let speedup4 = rows.iter().find(|r| r.0 == 4).unwrap().2;
    assert!(
        speedup4 >= 1.5,
        "concurrency 4 should beat sequential by ≥1.5x on a latency-bound run \
         (got {speedup4:.2}x)"
    );

    let report = Json::obj(vec![
        ("benchmark", Json::str("bench_concurrency_wall")),
        ("examples", Json::num(N as f64)),
        ("seed", Json::num(SEED as f64)),
        ("batch_size", Json::num(BATCH as f64)),
        ("latency_scale", Json::num(LATENCY_SCALE)),
        ("clock", Json::str("real (latency slept, scaled down)")),
        ("crossover_concurrency", Json::num(crossover as f64)),
        ("levels", Json::arr(by_level)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_concurrency_wall.json");
    std::fs::write(&out_path, report.to_pretty()).expect("writing BENCH_concurrency_wall.json");
    println!("results written to {}", out_path.display());
}
