//! Stats-based data-skipping benchmark: how many data files a point
//! lookup decompresses with per-file min/max stats on vs off, on a cache
//! that `optimize` has range-clustered (paper §3.2: Delta data skipping).
//!
//! Freshly flushed files each span nearly the whole SHA-256 key space, so
//! stats alone prune nothing; after `slleval cache optimize` the file
//! ranges are narrow and disjoint and a probe touches one file. The
//! headline assertion: over a sparse probe set (hits + guaranteed
//! misses) against 64+ clustered files, skipping decompresses at least
//! 5x fewer files. Results land in `BENCH_cache.json` at the repository
//! root.

use spark_llm_eval::cache::ResponseCache;
use spark_llm_eval::config::CachePolicy;
use spark_llm_eval::providers::InferenceResponse;
use spark_llm_eval::util::bench::section;
use spark_llm_eval::util::json::Json;
use std::time::Instant;

const N_ENTRIES: usize = 2048;
const FLUSH_EVERY: usize = 16;

fn resp(i: usize) -> InferenceResponse {
    InferenceResponse {
        text: format!("response body for probe {i} — lorem ipsum dolor sit amet"),
        input_tokens: 40,
        output_tokens: 12,
        latency_ms: 120.0,
        cost_usd: 0.001,
    }
}

fn probe_all(cache: &ResponseCache, prompts: &[String]) -> (u64, u64, f64, usize) {
    let start = Instant::now();
    let mut hits = 0usize;
    for p in prompts {
        if cache.get(p, "gpt-4o", "openai", 0.0, 256).unwrap().is_some() {
            hits += 1;
        }
    }
    let secs = start.elapsed().as_secs_f64();
    let s = cache.stats();
    (s.files_opened, s.files_skipped, secs, hits)
}

fn main() {
    let dir = std::env::temp_dir().join(format!("slleval-bench-skipping-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    section("build: 2048 entries across 128 flush commits");
    {
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.flush_every = FLUSH_EVERY;
        for i in 0..N_ENTRIES {
            cache.put(&format!("prompt-{i:04}"), "gpt-4o", "openai", 0.0, 256, &resp(i)).unwrap();
        }
        cache.flush().unwrap();
        let files_before = cache.table().state(None).unwrap().unwrap().files.len();
        println!("{files_before} flush files, every one a candidate for any probe");

        section("optimize: range-cluster on prompt_hash");
        let target = cache.storage_bytes().unwrap() / 100;
        let outcome = cache.optimize(target).unwrap();
        assert!(outcome.version.is_some(), "optimize must rewrite the flush files");
        let vacuumed = cache.vacuum(0, false).unwrap();
        let files_after = cache.table().state(None).unwrap().unwrap().files.len();
        assert!(
            files_after >= 64,
            "benchmark needs 64+ clustered files, got {files_after}"
        );
        println!(
            "{} -> {files_after} files ({} batches), vacuum reclaimed {} superseded files",
            files_before, outcome.metrics.num_batches, vacuumed.deleted_files
        );
    }

    // Sparse probe set: 12 known keys spread across the range plus 4
    // guaranteed misses (misses are skipping's best case — with stats off
    // they force a scan of every live file).
    let mut prompts: Vec<String> =
        (0..N_ENTRIES).step_by(N_ENTRIES / 12).map(|i| format!("prompt-{i:04}")).collect();
    for i in 0..4 {
        prompts.push(format!("never-cached-{i}"));
    }

    section("probe: stats-based skipping ON vs OFF (fresh handles)");
    let with = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
    with.set_skipping(true);
    let (opened_on, skipped_on, secs_on, hits_on) = probe_all(&with, &prompts);
    let without = ResponseCache::open(&dir, CachePolicy::ReadOnly).unwrap();
    without.set_skipping(false);
    let (opened_off, _, secs_off, hits_off) = probe_all(&without, &prompts);

    assert_eq!(hits_on, hits_off, "skipping must not change lookup results");
    assert_eq!(hits_on, prompts.len() - 4);
    let ratio = opened_off as f64 / opened_on.max(1) as f64;
    println!(
        "skipping ON : {opened_on} files opened, {skipped_on} skipped, {:.1} ms",
        secs_on * 1e3
    );
    println!("skipping OFF: {opened_off} files opened, {:.1} ms", secs_off * 1e3);
    println!("file-read reduction: {ratio:.1}x");
    assert!(
        opened_off >= 5 * opened_on.max(1),
        "expected >=5x fewer file reads with skipping: {opened_off} vs {opened_on}"
    );

    let report = Json::obj(vec![
        ("benchmark", Json::str("bench_cache_skipping")),
        ("entries", Json::num(N_ENTRIES as f64)),
        ("flush_every", Json::num(FLUSH_EVERY as f64)),
        ("probes", Json::num(prompts.len() as f64)),
        ("files_opened_skipping_on", Json::num(opened_on as f64)),
        ("files_skipped_by_stats", Json::num(skipped_on as f64)),
        ("files_opened_skipping_off", Json::num(opened_off as f64)),
        ("read_reduction", Json::num(ratio)),
        ("probe_secs_skipping_on", Json::num(secs_on)),
        ("probe_secs_skipping_off", Json::num(secs_off)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_cache.json");
    std::fs::write(&out_path, report.to_pretty()).expect("writing BENCH_cache.json");
    println!("\nresults written to {}", out_path.display());
    let _ = std::fs::remove_dir_all(&dir);
}
