//! Table 6 reproduction: cost across providers for 10k examples of
//! 400 input / 150 output tokens. Exact to the paper's price book.

use spark_llm_eval::providers::pricing::lookup;
use spark_llm_eval::report::tables::table6;
use spark_llm_eval::util::bench::section;

fn main() {
    section("Table 6 — cost comparison across providers");
    let (rows, text) = table6();
    println!("{text}");

    // §5.5 extrapolation: 1M examples.
    let full = lookup("openai", "gpt-4o").unwrap().workload_cost(1_000_000, 400, 150).2;
    let mini = lookup("openai", "gpt-4o-mini").unwrap().workload_cost(1_000_000, 400, 150).2;
    println!(
        "1M-example extrapolation: gpt-4o ${full:.0} vs gpt-4o-mini ${mini:.0} \
         ({:.0}x reduction; paper: ~$3,250 vs ~$150, 20x)",
        full / mini
    );
    assert!((rows[0].3 - 32.50).abs() < 1e-9);
    assert!((full - 3250.0).abs() < 1.0);
    assert!(full / mini > 20.0);
}
