//! Table 4 + §5.3 reproduction: caching effectiveness over evaluation
//! iterations (paper: initial 50k run $127.50 / 5.1 min; three replay
//! iterations ≈ $0 / ~24 s each; 75% cost and 69% time saved) plus the
//! cache storage-overhead measurement.

use spark_llm_eval::cache::ResponseCache;
use spark_llm_eval::config::CachePolicy;
use spark_llm_eval::providers::InferenceResponse;
use spark_llm_eval::report::tables::table4;
use spark_llm_eval::util::bench::{bench, section};

fn main() {
    section("Table 4 — caching effectiveness over evaluation iterations");
    let (rows, text) = table4(50_000);
    println!("{text}");
    assert_eq!(rows[1].api_calls, 0, "replay must make zero API calls");
    assert!(rows[1].secs < rows[0].secs / 3.0, "replay must be much faster");

    section("§5.3 — cache storage overhead (live Delta table)");
    // Insert entries shaped like the paper's workload (≈500-token prompts,
    // ≈200-token responses) and measure on-disk size, then extrapolate.
    let dir = std::env::temp_dir().join(format!("slleval-bench-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let n_probe = 5_000usize;
    {
        let mut cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
        cache.flush_every = 100_000; // single flush at the end
        let prompt_body = "lorem ipsum dolor sit amet consectetur ".repeat(50); // ~500 tokens
        let response_body = "adipiscing elit sed do eiusmod tempor ".repeat(20); // ~200 tokens
        for i in 0..n_probe {
            let resp = InferenceResponse {
                text: format!("{response_body} #{i}"),
                input_tokens: 500,
                output_tokens: 200,
                latency_ms: 350.0,
                cost_usd: 0.004,
            };
            cache
                .put(&format!("{prompt_body} #{i}"), "gpt-4o", "openai", 0.0, 1024, &resp)
                .unwrap();
        }
        cache.flush().unwrap();
        let bytes = cache.storage_bytes().unwrap();
        let per_entry = bytes as f64 / n_probe as f64;
        let extrapolated_mb = per_entry * 50_000.0 / 1e6;
        println!(
            "{n_probe} entries -> {:.1} MB on disk ({:.0} B/entry, gzip); \
             50k-entry extrapolation {:.0} MB (paper: ~180 MB with Parquet)",
            bytes as f64 / 1e6,
            per_entry,
            extrapolated_mb
        );
    }

    section("cache hot-path micro-benchmarks");
    let cache = ResponseCache::open(&dir, CachePolicy::Enabled).unwrap();
    let prompt = "lorem ipsum dolor sit amet consectetur ".repeat(50) + " #42";
    bench("cache.get (hit, in-memory index)", 50.0, || {
        std::hint::black_box(cache.get(&prompt, "gpt-4o", "openai", 0.0, 1024).unwrap());
    });
    bench("cache.get (miss)", 50.0, || {
        std::hint::black_box(cache.get("never cached", "gpt-4o", "openai", 0.0, 1024).unwrap());
    });
    bench("cache_key (sha256 of ~500-token prompt)", 50.0, || {
        std::hint::black_box(spark_llm_eval::cache::cache_key(&prompt, "gpt-4o", "openai", 0.0, 1024));
    });
    let _ = std::fs::remove_dir_all(&dir);
}
