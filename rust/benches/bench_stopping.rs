//! Adaptive-stopping benchmark: a full evaluation vs a wave-gated run
//! with a loose certification target on a large simulated dataset.
//!
//! This is ISSUE 9's acceptance gate: with `stopping` configured the run
//! must (a) save at least half of the inference calls at a loose ±0.075
//! target, (b) account every row as evaluated-or-saved, (c) certify
//! every metric at the target half-width, and (d) land its point
//! estimates inside the full run's confidence intervals — the saved
//! inference must not have bought a different answer. Results are
//! recorded in `BENCH_stopping.json` at the repository root.

use spark_llm_eval::config::{CachePolicy, CiMethod, EvalTask, MetricConfig, StoppingConfig};
use spark_llm_eval::coordinator::{EvalResult, EvalRunner};
use spark_llm_eval::data::synth;
use spark_llm_eval::providers::simulated::SimServiceConfig;
use spark_llm_eval::ratelimit::VirtualClock;
use spark_llm_eval::util::bench::section;
use spark_llm_eval::util::json::Json;
use std::time::Instant;

const ROWS: usize = 4000;
const SEED: u64 = 0x5709;
const TARGET_HALF_WIDTH: f64 = 0.075;

fn runner() -> EvalRunner {
    let mut r = EvalRunner::with_clock(VirtualClock::new());
    r.service_config = SimServiceConfig {
        server_error_rate: 0.0,
        unparseable_rate: 0.0,
        sleep_latency: false,
        ..Default::default()
    };
    r
}

fn task() -> EvalTask {
    let mut task = EvalTask::default();
    // Cache off and speculation/splitting off so api_calls counts exactly
    // one provider call per evaluated row — the quantity stopping saves.
    task.inference.cache_policy = CachePolicy::Disabled;
    task.scheduler.speculation = false;
    task.scheduler.adaptive_split = false;
    task.statistics.ci_method = CiMethod::Analytic;
    task.metrics = vec![
        MetricConfig::new("exact_match", "lexical"),
        MetricConfig::new("token_f1", "lexical"),
    ];
    task
}

fn run(task: &EvalTask) -> (f64, EvalResult) {
    let df = synth::generate_default(ROWS, SEED);
    let t = Instant::now();
    let result = runner().evaluate(&df, task).expect("bench run");
    (t.elapsed().as_secs_f64(), result)
}

fn main() {
    section(&format!(
        "adaptive stopping benchmark — {ROWS} rows, target ±{TARGET_HALF_WIDTH}"
    ));

    let full_task = task();
    let (t_full, full) = run(&full_task);
    assert_eq!(full.inference.api_calls, ROWS as u64, "full run pays for every row");

    let mut stopped_task = task();
    stopped_task.stopping = Some(StoppingConfig {
        ci_half_width: TARGET_HALF_WIDTH,
        alpha: 0.05,
        wave_size: 200,
        min_rows: 200,
        spend_alpha: true,
    });
    let (t_stopped, stopped) = run(&stopped_task);

    let s = &stopped.inference.sched;
    println!(
        "full {:>7.1}ms ({} calls) | stopped {:>7.1}ms ({} calls) | \
         {} waves, {} rows evaluated, {} rows saved",
        t_full * 1e3,
        full.inference.api_calls,
        t_stopped * 1e3,
        stopped.inference.api_calls,
        s.waves,
        s.rows_evaluated,
        s.rows_saved,
    );

    // Accounting gate: every row is evaluated or deliberately saved, and
    // the loose target saves at least half of the inference calls.
    assert_eq!(s.rows_evaluated + s.rows_saved, ROWS);
    assert_eq!(stopped.inference.api_calls, s.rows_evaluated as u64);
    assert!(
        2 * stopped.inference.api_calls <= full.inference.api_calls,
        "loose target must save ≥50% of inference calls \
         (full {}, stopped {})",
        full.inference.api_calls,
        stopped.inference.api_calls,
    );

    let mut metric_jsons = Vec::new();
    for m in &stopped.metrics {
        let f = full.metric(&m.name).expect("metric present in full run");
        let half_width = (m.ci.hi - m.ci.lo) / 2.0;
        println!(
            "{:<12} full {:.4} ({:.4}, {:.4}) | stopped {:.4} ±{:.4} \
             certified={:?} wave={:?}",
            m.name, f.value, f.ci.lo, f.ci.hi, m.value, half_width, m.certified, m.stopped_at_wave,
        );
        // Certification gate: every metric met the target half-width.
        assert_eq!(m.certified, Some(true), "{} must certify", m.name);
        assert!(
            half_width <= TARGET_HALF_WIDTH,
            "{}: final half-width {half_width:.4} exceeds the certified target",
            m.name
        );
        // Answer-preservation gate: the stopped estimate lies inside the
        // full run's CI — less inference, same statistical answer.
        assert!(
            f.ci.lo <= m.value && m.value <= f.ci.hi,
            "{}: stopped estimate {:.4} outside full-run CI ({:.4}, {:.4})",
            m.name,
            m.value,
            f.ci.lo,
            f.ci.hi,
        );
        metric_jsons.push(Json::obj(vec![
            ("name", Json::str(&m.name)),
            ("value_full", Json::num(f.value)),
            ("ci_full_lower", Json::num(f.ci.lo)),
            ("ci_full_upper", Json::num(f.ci.hi)),
            ("value_stopped", Json::num(m.value)),
            ("half_width_stopped", Json::num(half_width)),
            ("certified", Json::Bool(m.certified == Some(true))),
            (
                "stopped_at_wave",
                m.stopped_at_wave.map(|w| Json::num(w as f64)).unwrap_or(Json::Null),
            ),
        ]));
    }

    let report = Json::obj(vec![
        ("benchmark", Json::str("bench_stopping")),
        ("rows", Json::num(ROWS as f64)),
        ("target_half_width", Json::num(TARGET_HALF_WIDTH)),
        ("full_api_calls", Json::num(full.inference.api_calls as f64)),
        ("stopped_api_calls", Json::num(stopped.inference.api_calls as f64)),
        (
            "saved_fraction",
            Json::num(s.rows_saved as f64 / ROWS as f64),
        ),
        ("rows_evaluated", Json::num(s.rows_evaluated as f64)),
        ("rows_saved", Json::num(s.rows_saved as f64)),
        ("waves", Json::num(s.waves as f64)),
        ("full_secs", Json::num(t_full)),
        ("stopped_secs", Json::num(t_stopped)),
        ("metrics", Json::arr(metric_jsons)),
    ]);
    let out_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_stopping.json");
    std::fs::write(&out_path, report.to_pretty()).expect("writing BENCH_stopping.json");
    println!("\nresults written to {}", out_path.display());
}
