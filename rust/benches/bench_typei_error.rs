//! §5.4 reproduction: Type I error of the significance tests under the
//! null hypothesis (paper: McNemar 4.9%, paired t 5.1%, Wilcoxon 5.0% at
//! nominal α = 5% over 10,000 simulated comparisons).

use spark_llm_eval::report::tables::type_i_error;
use spark_llm_eval::stats::{paired_t_test, wilcoxon_signed_rank};
use spark_llm_eval::util::bench::{bench, section};
use spark_llm_eval::util::rng::Rng;

fn main() {
    section("§5.4 — Type I error calibration (10,000 null comparisons)");
    let (rows, text) = type_i_error(10_000, 100);
    println!("{text}");
    for r in &rows {
        assert!(
            (0.040..0.062).contains(&r.rate),
            "{} Type I rate {:.3} outside calibration band",
            r.test,
            r.rate
        );
    }

    section("significance-test micro-benchmarks");
    let mut rng = Rng::new(3);
    let a: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
    bench("paired_t_test        (n=1000)", 100.0, || {
        std::hint::black_box(paired_t_test(&a, &b));
    });
    bench("wilcoxon_signed_rank (n=1000)", 200.0, || {
        std::hint::black_box(wilcoxon_signed_rank(&a, &b));
    });
}
