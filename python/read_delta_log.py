#!/usr/bin/env python3
"""Stdlib-only Delta transaction-log reader (interop check for CI).

Replays a table written by the Rust storage subsystem (``rust/src/storage``)
using nothing but the Delta protocol: ``_delta_log/<version>.json`` commits
of single-action JSON lines, optional ``<start>.<end>.compacted.json``
shortcut files, and gzip-JSONL data files. Prints a summary JSON whose
fields match ``slleval cache ls --json --keys``, so CI can diff the two
documents and prove an external reader sees exactly the live-row set the
Rust writer reports.

Usage:
    python3 python/read_delta_log.py <table_dir> [--key-col COL]

Output (compact JSON, sorted keys):
    {"bytes": ..., "files": ..., "keys": [...], "rows": ...,
     "tombstones": ..., "version": ...}
"""

import argparse
import gzip
import json
import os
import sys

SUPPORTED_READER_VERSION = 1


def list_log(log_dir):
    """Committed versions (sorted) and compacted (start, end) ranges."""
    commits, compacted = [], []
    for name in os.listdir(log_dir):
        if not name.endswith(".json"):
            continue
        stem = name[: -len(".json")]
        if stem.endswith(".compacted"):
            parts = stem[: -len(".compacted")].split(".")
            if len(parts) == 2 and all(p.isdigit() for p in parts):
                compacted.append((int(parts[0]), int(parts[1])))
        elif stem.isdigit():
            commits.append(int(stem))
    commits.sort()
    return commits, compacted


def read_actions(path):
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)


def replay(table_dir):
    """Fold the log into (version, metadata, live files, tombstones)."""
    log_dir = os.path.join(table_dir, "_delta_log")
    commits, compacted = list_log(log_dir)
    if not commits:
        return None
    latest = commits[-1]
    start = 0
    sources = []
    full = [(s, e) for (s, e) in compacted if s == 0 and e <= latest]
    if full:
        s, e = max(full, key=lambda r: r[1])
        sources.append(os.path.join(log_dir, "%020d.%020d.compacted.json" % (s, e)))
        start = e + 1
    for v in range(start, latest + 1):
        sources.append(os.path.join(log_dir, "%020d.json" % v))

    metadata = None
    files = {}
    tombstones = {}
    for path in sources:
        for action in read_actions(path):
            if "protocol" in action:
                reader = action["protocol"].get("minReaderVersion", 1)
                if reader > SUPPORTED_READER_VERSION:
                    raise SystemExit(
                        "table requires reader protocol %d (supported: %d)"
                        % (reader, SUPPORTED_READER_VERSION)
                    )
            elif "metaData" in action:
                metadata = action["metaData"]
            elif "add" in action:
                add = action["add"]
                tombstones.pop(add["path"], None)
                files[add["path"]] = add
            elif "remove" in action:
                remove = action["remove"]
                files.pop(remove["path"], None)
                tombstones[remove["path"]] = remove
            # commitInfo / txn / cdc etc.: informational, skipped.
    return latest, metadata, files, tombstones


def key_column(metadata, override):
    if override:
        return override
    if metadata:
        cols = metadata.get("configuration", {}).get("slleval.statsColumns", "")
        cols = [c for c in cols.split(",") if c]
        if cols:
            return cols[0]
    return "prompt_hash"


def read_rows(table_dir, rel_path):
    with gzip.open(os.path.join(table_dir, rel_path), "rt", encoding="utf-8") as f:
        return [json.loads(line) for line in f if line.strip()]


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("table_dir")
    parser.add_argument("--key-col", default=None)
    args = parser.parse_args()

    state = replay(args.table_dir)
    if state is None:
        print(json.dumps({"version": None}, sort_keys=True, separators=(",", ":")))
        return
    version, metadata, files, tombstones = state

    key_col = key_column(metadata, args.key_col)
    rows = 0
    keys = set()
    for rel_path in sorted(files):
        for row in read_rows(args.table_dir, rel_path):
            rows += 1
            key = row.get(key_col)
            if isinstance(key, str):
                keys.add(key)

    summary = {
        "version": version,
        "files": len(files),
        "bytes": sum(int(f.get("size", 0)) for f in files.values()),
        "rows": rows,
        "tombstones": len(tombstones),
        "keys": sorted(keys),
    }
    print(json.dumps(summary, sort_keys=True, separators=(",", ":")))


if __name__ == "__main__":
    sys.exit(main())
