"""L2 correctness: SimLM encoder shapes/invariants and the bootstrap graph
vs its oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from compile.kernels.ref import bootstrap_means_ref
from compile.model import (
    SimLMConfig,
    bertscore_fn,
    bootstrap_fn,
    embed_fn,
    encode_tokens,
    init_params,
    param_specs,
)

CFG = SimLMConfig()
PARAMS = init_params(CFG)


def batch(seed=0, identical_rows=()):
    rng = np.random.default_rng(seed)
    ids = rng.integers(2, CFG.vocab_size, size=(CFG.batch, CFG.max_seq)).astype(
        np.int32
    )
    lengths = rng.integers(3, CFG.max_seq + 1, size=CFG.batch)
    mask = (np.arange(CFG.max_seq)[None, :] < lengths[:, None]).astype(np.float32)
    ids = ids * mask.astype(np.int32)
    return jnp.asarray(ids), jnp.asarray(mask)


def test_param_count_matches_specs():
    total = sum(int(np.prod(s)) for _, s in param_specs(CFG))
    got = sum(int(np.prod(p.shape)) for p in PARAMS.values())
    assert total == got


def test_token_embeddings_unit_norm():
    ids, mask = batch(0)
    tok = encode_tokens(PARAMS, ids, mask, CFG)
    assert tok.shape == (CFG.batch, CFG.max_seq, CFG.d_model)
    norms = np.linalg.norm(np.asarray(tok), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_pooled_embedding_unit_norm_and_shape():
    ids, mask = batch(1)
    (pooled,) = embed_fn(PARAMS, ids, mask, CFG)
    assert pooled.shape == (CFG.batch, CFG.d_model)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(pooled), axis=-1), 1.0, atol=1e-4
    )


def test_identical_ids_identical_embeddings():
    ids, mask = batch(2)
    ids = ids.at[1].set(ids[0])
    mask = mask.at[1].set(mask[0])
    (pooled,) = embed_fn(PARAMS, ids, mask, CFG)
    np.testing.assert_allclose(
        np.asarray(pooled[0]), np.asarray(pooled[1]), atol=1e-5
    )


def test_padding_content_does_not_leak():
    """Changing ids at masked positions must not change the embedding."""
    ids, mask = batch(3)
    (p1,) = embed_fn(PARAMS, ids, mask, CFG)
    dirty = np.asarray(ids).copy()
    dirty[np.asarray(mask) == 0.0] = 7
    (p2,) = embed_fn(PARAMS, jnp.asarray(dirty), mask, CFG)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-4)


def test_bertscore_fn_identity_rows():
    ids, mask = batch(4)
    p, r, f1 = bertscore_fn(PARAMS, ids, mask, ids, mask, CFG)
    np.testing.assert_allclose(np.asarray(f1), 1.0, atol=1e-4)


@settings(deadline=None, max_examples=20, derandomize=True)
@given(n=st.integers(1, 64), r=st.integers(1, 32), seed=st.integers(0, 10**6))
def test_bootstrap_fn_matches_ref(n, r, seed):
    rng = np.random.default_rng(seed)
    max_n = 64
    values = np.zeros(max_n, dtype=np.float32)
    values[:n] = rng.standard_normal(n).astype(np.float32)
    idx = rng.integers(0, n, size=(r, max_n)).astype(np.int32)
    mask = np.zeros((r, max_n), dtype=np.float32)
    mask[:, :n] = 1.0
    (got,) = bootstrap_fn(jnp.asarray(values), jnp.asarray(idx), jnp.asarray(mask))
    want = bootstrap_means_ref(jnp.asarray(values), jnp.asarray(idx), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_deterministic_weights():
    p1 = init_params(CFG)
    p2 = init_params(CFG)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
