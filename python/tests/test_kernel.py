"""L1 correctness: the fused Pallas BERTScore kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, tile sizes, dtypes, and mask patterns — the core
correctness signal for the kernel (see DESIGN.md §5).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.bertscore import bertscore_max_sim, bertscore_prf
from compile.kernels.ref import bertscore_max_sim_ref, bertscore_prf_ref

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=30, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def make_inputs(rng, batch, m, n, d, frac_masked=0.3):
    a = rng.standard_normal((batch, m, d)).astype(np.float32)
    b = rng.standard_normal((batch, n, d)).astype(np.float32)
    a /= np.maximum(np.linalg.norm(a, axis=-1, keepdims=True), 1e-8)
    b /= np.maximum(np.linalg.norm(b, axis=-1, keepdims=True), 1e-8)
    # Prefix masks (realistic padding) with at least one valid token.
    la = rng.integers(1, m + 1, size=batch)
    lb = rng.integers(1, n + 1, size=batch)
    mask_a = (np.arange(m)[None, :] < la[:, None]).astype(np.float32)
    mask_b = (np.arange(n)[None, :] < lb[:, None]).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(mask_a), jnp.asarray(mask_b)


@given(
    batch=st.integers(1, 4),
    gm=st.integers(1, 3),
    gn=st.integers(1, 3),
    tile=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_swept(batch, gm, gn, tile, d, seed):
    m, n = gm * tile, gn * tile
    rng = np.random.default_rng(seed)
    a, b, ma, mb = make_inputs(rng, batch, m, n, d)

    row_k, col_k = bertscore_max_sim(a, b, ma, mb, tile_m=tile, tile_n=tile)
    row_r, col_r = bertscore_max_sim_ref(a, b, ma, mb)

    # Compare only at valid positions (masked rows differ in sentinel only).
    np.testing.assert_allclose(
        np.asarray(row_k * ma), np.asarray(row_r * ma), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(col_k * mb), np.asarray(col_r * mb), rtol=1e-5, atol=1e-5
    )

    pk, rk, fk = bertscore_prf(a, b, ma, mb, tile_m=tile, tile_n=tile)
    pr, rr, fr = bertscore_prf_ref(a, b, ma, mb)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fr), rtol=1e-5, atol=1e-5)


def test_identical_inputs_score_one():
    rng = np.random.default_rng(0)
    a, _, ma, _ = make_inputs(rng, 3, 32, 32, 64)
    p, r, f1 = bertscore_prf(a, a, ma, ma)
    np.testing.assert_allclose(np.asarray(p), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), 1.0, atol=1e-5)


def test_rectangular_tiles():
    rng = np.random.default_rng(1)
    a, b, ma, mb = make_inputs(rng, 2, 64, 32, 32)
    pk, rk, fk = bertscore_prf(a, b, ma, mb, tile_m=32, tile_n=16)
    pr, rr, fr = bertscore_prf_ref(a, b, ma, mb)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fr), rtol=1e-5, atol=1e-5)


def test_scores_bounded():
    rng = np.random.default_rng(2)
    a, b, ma, mb = make_inputs(rng, 4, 32, 32, 64)
    p, r, f1 = bertscore_prf(a, b, ma, mb)
    # Unit-norm rows → cosine in [-1, 1].
    assert np.all(np.asarray(p) <= 1.0 + 1e-6)
    assert np.all(np.asarray(r) <= 1.0 + 1e-6)
    assert np.all(np.asarray(f1) <= 1.0 + 1e-6)


def test_mask_invariance_of_padding_content():
    """Garbage in padded positions must not change the scores."""
    rng = np.random.default_rng(3)
    a, b, ma, mb = make_inputs(rng, 2, 32, 32, 64)
    a2 = np.asarray(a).copy()
    b2 = np.asarray(b).copy()
    a2[np.asarray(ma) == 0.0] = 99.0
    b2[np.asarray(mb) == 0.0] = -99.0
    f1_clean = bertscore_prf(a, b, ma, mb)[2]
    f1_dirty = bertscore_prf(jnp.asarray(a2), jnp.asarray(b2), ma, mb)[2]
    np.testing.assert_allclose(
        np.asarray(f1_clean), np.asarray(f1_dirty), rtol=1e-5, atol=1e-5
    )


def test_tile_size_must_divide():
    rng = np.random.default_rng(4)
    a, b, ma, mb = make_inputs(rng, 1, 30, 32, 16)
    with pytest.raises(ValueError):
        bertscore_max_sim(a, b, ma, mb, tile_m=16, tile_n=16)


def test_kernel_under_jit():
    """The kernel must lower inside jit (the AOT path depends on this)."""
    rng = np.random.default_rng(5)
    a, b, ma, mb = make_inputs(rng, 2, 32, 32, 64)

    @jax.jit
    def f(a, b, ma, mb):
        return bertscore_prf(a, b, ma, mb)

    p, r, f1 = f(a, b, ma, mb)
    pr, rr, fr = bertscore_prf_ref(a, b, ma, mb)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(fr), rtol=1e-5, atol=1e-5)
