"""AOT emitter contract tests: the manifest, weight blob, and HLO text
artifacts that the Rust runtime consumes."""

import hashlib
import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _need_artifacts():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        pytest.skip("run `make artifacts` first")


def manifest():
    _need_artifacts()
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_schema():
    m = manifest()
    assert m["format_version"] == 1
    for key in ["vocab_size", "d_model", "n_heads", "n_layers", "max_seq", "batch"]:
        assert m["model"][key] > 0
    assert m["model"]["d_model"] % m["model"]["n_heads"] == 0
    assert m["bootstrap"]["resamples"] > 0
    assert set(m["artifacts"]) == {"embedder", "bertscore", "bootstrap"}


def test_weight_blob_matches_manifest():
    m = manifest()
    blob_path = os.path.join(ART, m["weights"]["file"])
    blob = open(blob_path, "rb").read()
    total = sum(int(np.prod(p["shape"])) for p in m["weights"]["params"])
    assert len(blob) == total * 4
    assert hashlib.sha256(blob).hexdigest() == m["weights"]["sha256"]


def test_weights_reproduce_from_seed():
    from compile.model import SimLMConfig, init_params, param_specs

    m = manifest()
    cfg = SimLMConfig(
        vocab_size=m["model"]["vocab_size"],
        d_model=m["model"]["d_model"],
        n_heads=m["model"]["n_heads"],
        n_layers=m["model"]["n_layers"],
        max_seq=m["model"]["max_seq"],
        d_ff=m["model"]["d_ff"],
        batch=m["model"]["batch"],
        seed=m["model"]["seed"],
    )
    params = init_params(cfg)
    blob = b"".join(
        np.asarray(params[name], dtype="<f4").tobytes() for name, _ in param_specs(cfg)
    )
    assert hashlib.sha256(blob).hexdigest() == m["weights"]["sha256"]


def test_hlo_text_artifacts_look_like_hlo():
    m = manifest()
    n_params = len(m["weights"]["params"])
    for name, art in m["artifacts"].items():
        text = open(os.path.join(ART, art["file"])).read()
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text
        # Parameter count: weights + per-artifact inputs.
        expected_inputs = {
            "embedder": n_params + 2,
            "bertscore": n_params + 4,
            "bootstrap": 3,
        }[name]
        assert text.count("parameter(") >= expected_inputs, name


def test_fixtures_cover_all_artifacts():
    _need_artifacts()
    with open(os.path.join(ART, "fixtures.json")) as f:
        fx = json.load(f)
    assert set(fx) == {"embed", "bertscore", "bootstrap"}
    m = manifest()
    b, s = m["model"]["batch"], m["model"]["max_seq"]
    assert len(fx["embed"]["ids"]) == b * s
    assert len(fx["embed"]["pooled"]) == b * m["model"]["d_model"]
    assert len(fx["bertscore"]["f1"]) == b


def test_kernel_tile_divides_seq():
    m = manifest()
    assert m["model"]["max_seq"] % m["model"]["kernel_tile_m"] == 0
    assert m["model"]["max_seq"] % m["model"]["kernel_tile_n"] == 0
