"""Emit numeric fixtures for the Rust↔Python equivalence tests.

Computes the L2 graphs directly in JAX (not through the HLO artifacts) on
deterministic inputs and writes the expected outputs to
``artifacts/fixtures.json``. The Rust integration test feeds the identical
token-id inputs through the compiled PJRT artifacts and asserts allclose —
this is the end-to-end proof that the AOT bridge preserves numerics.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from compile.model import (
    BootstrapConfig,
    SimLMConfig,
    bertscore_fn,
    bootstrap_fn,
    embed_fn,
    init_params,
)


def det_ids(cfg: SimLMConfig, salt: int) -> np.ndarray:
    """Deterministic pseudo-token batch: mixed lengths, ids in [2, vocab)."""
    b, s = cfg.batch, cfg.max_seq
    ids = np.zeros((b, s), dtype=np.int32)
    for i in range(b):
        length = 3 + (i * 7 + salt) % (s - 3)
        for j in range(length):
            ids[i, j] = 2 + (i * 131 + j * 17 + salt * 101) % (cfg.vocab_size - 2)
    return ids


def mask_of(ids: np.ndarray) -> np.ndarray:
    return (ids != 0).astype(np.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    cfg = SimLMConfig()
    bcfg = BootstrapConfig()
    params = init_params(cfg)

    ids_a = det_ids(cfg, salt=1)
    ids_b = det_ids(cfg, salt=2)
    # Make a couple of rows identical across a/b so BERTScore ≈ 1 there.
    ids_b[0] = ids_a[0]
    ids_b[1] = ids_a[1]
    mask_a, mask_b = mask_of(ids_a), mask_of(ids_b)

    (pooled,) = embed_fn(params, jnp.asarray(ids_a), jnp.asarray(mask_a), cfg)
    p, r, f1 = bertscore_fn(
        params,
        jnp.asarray(ids_a),
        jnp.asarray(mask_a),
        jnp.asarray(ids_b),
        jnp.asarray(mask_b),
        cfg,
    )

    # Bootstrap fixture: fixed values, fixed index pattern.
    n = 37
    values = np.zeros(bcfg.max_n, dtype=np.float32)
    values[:n] = np.arange(n, dtype=np.float32) * 0.25 - 2.0
    idx = np.zeros((bcfg.resamples, bcfg.max_n), dtype=np.int32)
    bmask = np.zeros((bcfg.resamples, bcfg.max_n), dtype=np.float32)
    for row in range(bcfg.resamples):
        for j in range(n):
            idx[row, j] = (row * 13 + j * 7) % n
            bmask[row, j] = 1.0
    (means,) = bootstrap_fn(
        jnp.asarray(values), jnp.asarray(idx), jnp.asarray(bmask)
    )

    fixtures = {
        "embed": {
            "ids": ids_a.flatten().tolist(),
            "mask": mask_a.flatten().tolist(),
            "pooled": np.asarray(pooled).flatten().tolist(),
        },
        "bertscore": {
            "ids_a": ids_a.flatten().tolist(),
            "mask_a": mask_a.flatten().tolist(),
            "ids_b": ids_b.flatten().tolist(),
            "mask_b": mask_b.flatten().tolist(),
            "precision": np.asarray(p).tolist(),
            "recall": np.asarray(r).tolist(),
            "f1": np.asarray(f1).tolist(),
        },
        "bootstrap": {
            "n": n,
            "values": values[:n].tolist(),
            "idx_rule": "idx[row,j] = (row*13 + j*7) % n",
            "means_head": np.asarray(means)[:32].tolist(),
            "means_mean": float(np.asarray(means).mean()),
        },
    }
    path = os.path.join(args.out, "fixtures.json")
    with open(path, "w") as f:
        json.dump(fixtures, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
