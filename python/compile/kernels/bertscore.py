"""L1 Pallas kernel: fused BERTScore token-similarity max-matching.

The BERTScore greedy matching needs, for candidate token embeddings
``A in R^{M x D}`` and reference token embeddings ``B in R^{N x D}`` (both
rows unit-normalised so the dot product is cosine similarity):

    row_max[m] = max_n  (A @ B^T)[m, n]   over valid reference tokens n
    col_max[n] = max_m  (A @ B^T)[m, n]   over valid candidate tokens m

GPU reference implementations materialise the full ``M x N`` similarity
matrix in HBM and run separate reduction kernels.  The TPU rethink (see
DESIGN.md §Hardware-Adaptation): tile ``A`` and ``B`` into MXU-friendly
blocks streamed through VMEM with ``BlockSpec``; each grid step computes one
``TM x TN`` tile of ``S`` on the MXU and folds it **immediately** into
running ``row_max`` / ``col_max`` accumulators, so ``S`` never leaves VMEM.
Masking of ragged sequence lengths happens inside the tile with additive
``-inf`` penalties.

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the grid is iterated sequentially in interpret mode,
which the accumulator-revisiting scheme relies on (same guarantee the TPU
backend gives for revisited output blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e9  # additive mask penalty; large enough to lose every max


def _bertscore_kernel(a_ref, b_ref, ma_ref, mb_ref, row_ref, col_ref):
    """One (batch, i, j) grid step.

    Block shapes:
      a_ref   (1, TM, D)   candidate tile
      b_ref   (1, TN, D)   reference tile
      ma_ref  (1, TM)      candidate validity mask (1.0 valid / 0.0 pad)
      mb_ref  (1, TN)      reference validity mask
      row_ref (1, TM)      running row max  (revisited across j)
      col_ref (1, TN)      running col max  (revisited across i)
    """
    i = pl.program_id(1)
    j = pl.program_id(2)

    a = a_ref[0]  # (TM, D)
    b = b_ref[0]  # (TN, D)
    ma = ma_ref[0]  # (TM,)
    mb = mb_ref[0]  # (TN,)

    # (TM, TN) similarity tile on the MXU. f32 accumulate.
    s = jnp.dot(a, b.T, preferred_element_type=jnp.float32)

    # Row max must ignore padded reference tokens; col max must ignore
    # padded candidate tokens.
    s_row = s + (mb - 1.0)[None, :] * (-NEG)  # -inf where mb == 0
    s_col = s + (ma - 1.0)[:, None] * (-NEG)  # -inf where ma == 0

    tile_row = jnp.max(s_row, axis=1)  # (TM,)
    tile_col = jnp.max(s_col, axis=0)  # (TN,)

    # Initialise accumulators on first visit, fold on revisits.  The grid
    # order is (batch, i, j) with j minor, so row_ref[bi, i] is first seen
    # at j == 0 and col_ref[bi, j] at i == 0.
    prev_row = jnp.where(j == 0, jnp.full_like(tile_row, NEG), row_ref[0])
    prev_col = jnp.where(i == 0, jnp.full_like(tile_col, NEG), col_ref[0])
    row_ref[0] = jnp.maximum(prev_row, tile_row)
    col_ref[0] = jnp.maximum(prev_col, tile_col)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n"))
def bertscore_max_sim(a, b, mask_a, mask_b, tile_m: int = 32, tile_n: int = 32):
    """Fused max-similarity accumulators for BERTScore.

    Args:
      a:      (BATCH, M, D) unit-norm candidate token embeddings.
      b:      (BATCH, N, D) unit-norm reference token embeddings.
      mask_a: (BATCH, M) float validity mask.
      mask_b: (BATCH, N) float validity mask.
      tile_m, tile_n: VMEM tile sizes (must divide M / N).

    Returns:
      (row_max (BATCH, M), col_max (BATCH, N)) — masked positions hold NEG.
    """
    batch, m, d = a.shape
    n = b.shape[1]
    if m % tile_m or n % tile_n:
        raise ValueError(f"tile sizes ({tile_m},{tile_n}) must divide ({m},{n})")
    gm, gn = m // tile_m, n // tile_n

    grid = (batch, gm, gn)
    return pl.pallas_call(
        _bertscore_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_m, d), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, tile_n, d), lambda bi, i, j: (bi, j, 0)),
            pl.BlockSpec((1, tile_m), lambda bi, i, j: (bi, i)),
            pl.BlockSpec((1, tile_n), lambda bi, i, j: (bi, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_m), lambda bi, i, j: (bi, i)),
            pl.BlockSpec((1, tile_n), lambda bi, i, j: (bi, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, m), jnp.float32),
            jax.ShapeDtypeStruct((batch, n), jnp.float32),
        ],
        interpret=True,
    )(a, b, mask_a, mask_b)


def bertscore_prf(a, b, mask_a, mask_b, tile_m: int = 32, tile_n: int = 32):
    """BERTScore precision / recall / F1 per batch element.

    Precision averages row_max over valid candidate tokens, recall averages
    col_max over valid reference tokens (Zhang et al., 2020, without idf
    weighting), F1 is their harmonic mean.
    """
    row_max, col_max = bertscore_max_sim(
        a, b, mask_a, mask_b, tile_m=tile_m, tile_n=tile_n
    )
    na = jnp.maximum(jnp.sum(mask_a, axis=1), 1.0)
    nb = jnp.maximum(jnp.sum(mask_b, axis=1), 1.0)
    p = jnp.sum(row_max * mask_a, axis=1) / na
    r = jnp.sum(col_max * mask_b, axis=1) / nb
    f1 = 2.0 * p * r / jnp.maximum(p + r, 1e-8)
    return p, r, f1
