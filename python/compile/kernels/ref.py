"""Pure-jnp oracle implementations for the Pallas kernels.

These materialise the full similarity matrix the naive way (the thing the
fused kernel avoids) and are the ground truth for the pytest / hypothesis
correctness sweeps in ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG = -1e9


def bertscore_max_sim_ref(a, b, mask_a, mask_b):
    """Unfused reference: full (BATCH, M, N) similarity matrix in memory."""
    s = jnp.einsum("bmd,bnd->bmn", a, b)
    s_row = jnp.where(mask_b[:, None, :] > 0.0, s, NEG)
    s_col = jnp.where(mask_a[:, :, None] > 0.0, s, NEG)
    row_max = jnp.max(s_row, axis=2)
    col_max = jnp.max(s_col, axis=1)
    return row_max, col_max


def bertscore_prf_ref(a, b, mask_a, mask_b):
    row_max, col_max = bertscore_max_sim_ref(a, b, mask_a, mask_b)
    na = jnp.maximum(jnp.sum(mask_a, axis=1), 1.0)
    nb = jnp.maximum(jnp.sum(mask_b, axis=1), 1.0)
    p = jnp.sum(row_max * mask_a, axis=1) / na
    r = jnp.sum(col_max * mask_b, axis=1) / nb
    f1 = 2.0 * p * r / jnp.maximum(p + r, 1e-8)
    return p, r, f1


def bootstrap_means_ref(values, idx, mask):
    """Reference for the bootstrap resample-mean graph.

    values: (N,), idx: (R, N) int32 indices into values, mask: (R, N) 0/1.
    Returns (R,) masked means of the gathered resamples.
    """
    gathered = values[idx]
    return jnp.sum(gathered * mask, axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1), 1.0
    )
