"""AOT emitter: lower the L2 graphs to HLO *text* + weight blobs.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser on
the Rust side reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Outputs (``make artifacts``):

  artifacts/embedder.hlo.txt    params: [weights..., ids (B,S) s32, mask (B,S) f32]
                                returns ((B,D) f32,)
  artifacts/bertscore.hlo.txt   params: [weights..., ids_a, mask_a, ids_b, mask_b]
                                returns ((B,) p, (B,) r, (B,) f1)
  artifacts/bootstrap.hlo.txt   params: [values (N,) f32, idx (R,N) s32, mask (R,N) f32]
                                returns ((R,) means,)
  artifacts/weights.bin         all weight tensors, f32 LE, manifest order
  artifacts/manifest.json       model config, parameter table, artifact index
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import (
    BootstrapConfig,
    SimLMConfig,
    bertscore_fn,
    bootstrap_fn,
    embed_fn,
    init_params,
    param_specs,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, cfg: SimLMConfig, bcfg: BootstrapConfig) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg)
    specs = param_specs(cfg)
    flat = [params[name] for name, _ in specs]

    ids_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq), jnp.int32)
    mask_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.max_seq), jnp.float32)
    weight_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in specs
    ]

    artifacts = {}

    # --- embedder ---------------------------------------------------------
    def embed_wrapped(*args):
        ws, ids, mask = args[:-2], args[-2], args[-1]
        p = {name: w for (name, _), w in zip(specs, ws)}
        return embed_fn(p, ids, mask, cfg)

    lowered = jax.jit(embed_wrapped).lower(*weight_specs, ids_spec, mask_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "embedder.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    artifacts["embedder"] = {
        "file": "embedder.hlo.txt",
        "inputs": ["weights", "ids", "mask"],
        "outputs": [["pooled", [cfg.batch, cfg.d_model], "f32"]],
    }

    # --- bertscore --------------------------------------------------------
    def bert_wrapped(*args):
        ws = args[: len(specs)]
        ids_a, mask_a, ids_b, mask_b = args[len(specs):]
        p = {name: w for (name, _), w in zip(specs, ws)}
        return bertscore_fn(p, ids_a, mask_a, ids_b, mask_b, cfg)

    lowered = jax.jit(bert_wrapped).lower(
        *weight_specs, ids_spec, mask_spec, ids_spec, mask_spec
    )
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "bertscore.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    artifacts["bertscore"] = {
        "file": "bertscore.hlo.txt",
        "inputs": ["weights", "ids_a", "mask_a", "ids_b", "mask_b"],
        "outputs": [
            ["precision", [cfg.batch], "f32"],
            ["recall", [cfg.batch], "f32"],
            ["f1", [cfg.batch], "f32"],
        ],
    }

    # --- bootstrap --------------------------------------------------------
    values_spec = jax.ShapeDtypeStruct((bcfg.max_n,), jnp.float32)
    idx_spec = jax.ShapeDtypeStruct((bcfg.resamples, bcfg.max_n), jnp.int32)
    rmask_spec = jax.ShapeDtypeStruct((bcfg.resamples, bcfg.max_n), jnp.float32)
    lowered = jax.jit(bootstrap_fn).lower(values_spec, idx_spec, rmask_spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "bootstrap.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    artifacts["bootstrap"] = {
        "file": "bootstrap.hlo.txt",
        "inputs": ["values", "idx", "mask"],
        "outputs": [["means", [bcfg.resamples], "f32"]],
    }

    # --- weights ----------------------------------------------------------
    blob = b"".join(
        np.asarray(w, dtype="<f4").tobytes(order="C") for w in flat
    )
    wpath = os.path.join(out_dir, "weights.bin")
    with open(wpath, "wb") as f:
        f.write(blob)

    manifest = {
        "format_version": 1,
        "model": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "max_seq": cfg.max_seq,
            "d_ff": cfg.d_ff,
            "batch": cfg.batch,
            "seed": cfg.seed,
            "kernel_tile_m": cfg.kernel_tile_m,
            "kernel_tile_n": cfg.kernel_tile_n,
        },
        "bootstrap": {"resamples": bcfg.resamples, "max_n": bcfg.max_n},
        "weights": {
            "file": "weights.bin",
            "dtype": "f32",
            "sha256": hashlib.sha256(blob).hexdigest(),
            "params": [
                {"name": name, "shape": list(shape)} for name, shape in specs
            ],
        },
        "artifacts": artifacts,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    manifest = emit(args.out, SimLMConfig(), BootstrapConfig())
    total = sum(
        int(np.prod(p["shape"])) for p in manifest["weights"]["params"]
    )
    print(
        f"emitted {len(manifest['artifacts'])} artifacts to {args.out} "
        f"({total} weights, sha256={manifest['weights']['sha256'][:12]}...)"
    )


if __name__ == "__main__":
    main()
