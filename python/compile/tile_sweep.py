"""L1 perf: Pallas BERTScore kernel tile-size sweep.

interpret=True timings are CPU-numpy, not a TPU proxy — the sweep reports
both the **measured CPU wall time** of the lowered graph (what the Rust
runtime pays per batch in this reproduction) and the **structural TPU
estimates**: VMEM working set per grid step and MXU utilization of the
tile matmul. Results land in EXPERIMENTS.md §Perf.

Usage: python -m compile.tile_sweep
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import SimLMConfig, bertscore_fn, init_params


def vmem_bytes(tm: int, tn: int, d: int) -> int:
    """Working set of one grid step: A-tile + B-tile + S-tile + accums."""
    return 4 * (tm * d + tn * d + tm * tn + tm + tn)


def mxu_utilization(tm: int, tn: int, d: int) -> float:
    """Fraction of 128x128 MXU tiles fully occupied by the (tm, d)x(d, tn)
    matmul (dimension-padding model)."""

    def eff(dim, unit):
        import math

        return dim / (math.ceil(dim / unit) * unit)

    return eff(tm, 128) * eff(tn, 128) * eff(d, 128)


def main() -> None:
    base = SimLMConfig()
    params = init_params(base)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(
        rng.integers(2, base.vocab_size, size=(base.batch, base.max_seq)).astype(np.int32)
    )
    mask = jnp.ones((base.batch, base.max_seq), jnp.float32)

    print(f"{'tile':>8} {'grid':>8} {'VMEM/step':>10} {'MXU util':>9} {'CPU ms/batch':>13}")
    for tile in [8, 16, 32, 64]:
        cfg = SimLMConfig(kernel_tile_m=tile, kernel_tile_n=tile)

        fn = jax.jit(lambda ia, ma, ib, mb: bertscore_fn(params, ia, ma, ib, mb, cfg))
        fn(ids, mask, ids, mask)[2].block_until_ready()  # compile
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            fn(ids, mask, ids, mask)[2].block_until_ready()
        ms = (time.perf_counter() - t0) / reps * 1e3

        gm = base.max_seq // tile
        grid = base.batch * gm * gm
        print(
            f"{tile:>8} {grid:>8} {vmem_bytes(tile, tile, base.d_model) / 1024:>9.1f}K "
            f"{mxu_utilization(tile, tile, base.d_model):>9.3f} {ms:>13.2f}"
        )


if __name__ == "__main__":
    main()
