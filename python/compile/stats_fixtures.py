"""Generate scipy reference fixtures for the Rust statistics stack.

Written at `make artifacts` time to ``artifacts/stats_fixtures.json``;
``rust/tests/stats_golden.rs`` replays every case against the from-scratch
Rust implementations. This is the cross-validation the paper performs
against scipy.stats / arch (§5.4).
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np
from scipy import special, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    rng = np.random.default_rng(20260710)
    fx: dict = {}

    # --- special functions -------------------------------------------------
    xs = [0.1, 0.5, 1.0, 2.5, 7.0, 15.5]
    fx["ln_gamma"] = [[x, float(special.gammaln(x))] for x in xs]
    fx["erf"] = [[x, float(special.erf(x))] for x in [-2.0, -0.5, 0.0, 0.3, 1.0, 2.5]]
    fx["normal_cdf"] = [[z, float(stats.norm.cdf(z))] for z in [-3.0, -1.0, 0.0, 0.5, 1.96, 3.2]]
    fx["normal_ppf"] = [[p, float(stats.norm.ppf(p))] for p in [0.001, 0.025, 0.3, 0.5, 0.9, 0.999]]
    fx["t_cdf"] = [
        [t, df, float(stats.t.cdf(t, df))]
        for t, df in [(-2.0, 5), (0.0, 3), (1.5, 10), (2.5, 30), (1.0, 100)]
    ]
    fx["t_ppf"] = [
        [p, df, float(stats.t.ppf(p, df))]
        for p, df in [(0.025, 9), (0.975, 9), (0.05, 30), (0.95, 100)]
    ]
    fx["chi2_cdf"] = [
        [x, df, float(stats.chi2.cdf(x, df))]
        for x, df in [(1.0, 1), (3.84, 1), (5.0, 3), (10.0, 5)]
    ]
    fx["beta_inc"] = [
        [a, b, x, float(special.betainc(a, b, x))]
        for a, b, x in [(2, 3, 0.5), (0.5, 0.5, 0.3), (5, 2, 0.8), (1, 1, 0.4)]
    ]

    # --- paired tests on fixed datasets -------------------------------------
    cases = []
    for n in [8, 25, 60]:
        a = rng.normal(0.0, 1.0, n)
        b = a + rng.normal(0.1, 0.5, n)
        t_res = stats.ttest_rel(a, b)
        try:
            w_res = stats.wilcoxon(a, b)
            w_p = float(w_res.pvalue)
            w_stat = float(w_res.statistic)
        except ValueError:
            w_p, w_stat = 1.0, 0.0
        cases.append(
            {
                "a": a.tolist(),
                "b": b.tolist(),
                "t_statistic": float(t_res.statistic),
                "t_pvalue": float(t_res.pvalue),
                "wilcoxon_statistic": w_stat,
                "wilcoxon_pvalue": w_p,
            }
        )
    fx["paired_tests"] = cases

    # --- mcnemar (binary paired) --------------------------------------------
    mc = []
    for (b01, b10, both) in [(3, 5, 20), (2, 1, 5), (30, 12, 50), (0, 0, 10)]:
        a = [1.0] * b10 + [0.0] * b01 + [1.0] * both
        b = [0.0] * b10 + [1.0] * b01 + [1.0] * both
        n_disc = b01 + b10
        if n_disc == 0:
            p = 1.0
        elif n_disc < 10:
            p = float(stats.binomtest(min(b01, b10), n_disc, 0.5).pvalue)
        else:
            # Uncorrected chi^2 (see rust/src/stats/tests.rs rationale).
            chi2 = (b01 - b10) ** 2 / n_disc
            p = float(1.0 - stats.chi2.cdf(chi2, 1))
        mc.append({"a": a, "b": b, "pvalue": p})
    fx["mcnemar"] = mc

    # --- shapiro-wilk ---------------------------------------------------------
    sw = []
    for n, dist in [(20, "norm"), (50, "lognorm"), (11, "outlier")]:
        if dist == "norm":
            x = rng.normal(0, 1, n)
        elif dist == "lognorm":
            x = rng.lognormal(0, 0.8, n)
        else:
            x = np.array([148, 154, 158, 160, 161, 162, 166, 170, 182, 195, 236.0])
        w, p = stats.shapiro(x)
        sw.append({"x": x.tolist(), "w": float(w), "p": float(p)})
    fx["shapiro"] = sw

    # --- wilson intervals -------------------------------------------------------
    # statsmodels-free reference: closed-form Wilson.
    def wilson(k, n, level=0.95):
        z = stats.norm.ppf(1 - (1 - level) / 2)
        p = k / n
        denom = 1 + z**2 / n
        center = (p + z**2 / (2 * n)) / denom
        half = z * np.sqrt(p * (1 - p) / n + z**2 / (4 * n**2)) / denom
        return [max(center - half, 0.0), min(center + half, 1.0)]

    fx["wilson"] = [[k, n] + wilson(k, n) for k, n in [(8, 10), (0, 20), (20, 20), (73, 100)]]

    # --- t interval -----------------------------------------------------------
    ti = []
    for n in [5, 30]:
        x = rng.normal(2.0, 1.5, n)
        lo, hi = stats.t.interval(0.95, n - 1, loc=np.mean(x), scale=stats.sem(x))
        ti.append({"x": x.tolist(), "lo": float(lo), "hi": float(hi)})
    fx["t_interval"] = ti

    path = os.path.join(args.out, "stats_fixtures.json")
    with open(path, "w") as f:
        json.dump(fx, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
