"""L2: JAX compute graphs for the semantic-metric stage.

Three graphs are AOT-lowered by ``aot.py`` and executed from the Rust
coordinator via PJRT (Python never runs on the request path):

  * ``embed``      — SimLM encoder: token ids -> pooled unit sentence
                     embedding (embedding-similarity metric).
  * ``bertscore``  — SimLM encoder over candidate + reference, then the L1
                     Pallas max-matching kernel -> per-example P/R/F1
                     (BERTScore metric).
  * ``bootstrap``  — batched bootstrap resample means (statistical
                     aggregation stage offload).

SimLM is a real transformer encoder (token+position embeddings, N pre-LN
blocks of multi-head attention + GELU MLP, masked mean pooling, L2
normalisation) with deterministic seeded weights standing in for MiniLM /
roberta-large, which we cannot ship (DESIGN.md §1).  Weights are *graph
parameters*, not baked constants: ``aot.py`` writes them to
``artifacts/weights.bin`` + ``manifest.json`` and the Rust runtime feeds
them back per call, which keeps the HLO text small and mirrors how a real
deployment would swap checkpoints.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.bertscore import bertscore_prf


@dataclass(frozen=True)
class SimLMConfig:
    """Encoder hyper-parameters. Sizes chosen so a CPU batch is ~ms-scale."""

    vocab_size: int = 4096
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    max_seq: int = 64
    d_ff: int = 512
    batch: int = 16            # fixed AOT batch; Rust pads the tail batch
    seed: int = 0
    kernel_tile_m: int = 64
    kernel_tile_n: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Deterministic parameter order — the manifest and the Rust runtime both
# depend on this exact ordering.
def param_specs(cfg: SimLMConfig) -> list[tuple[str, tuple[int, ...]]]:
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("tok_embed", (cfg.vocab_size, cfg.d_model)),
        ("pos_embed", (cfg.max_seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layers):
        p = f"layer{layer}."
        specs += [
            (p + "ln1_scale", (cfg.d_model,)),
            (p + "ln1_bias", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_scale", (cfg.d_model,)),
            (p + "ln2_bias", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    specs += [("lnf_scale", (cfg.d_model,)), ("lnf_bias", (cfg.d_model,))]
    return specs


def init_params(cfg: SimLMConfig) -> dict[str, jax.Array]:
    """Seeded deterministic init: N(0, 0.02) matrices, LN scale=1 bias=0."""
    key = jax.random.PRNGKey(cfg.seed)
    params: dict[str, jax.Array] = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_bias") or name.endswith(".b1") or name.endswith(".b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(x, mask, wq, wk, wv, wo, n_heads):
    b, s, d = x.shape
    hd = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    logits = jnp.where(mask[:, None, None, :] > 0.0, logits, -1e9)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def encode_tokens(params, ids, mask, cfg: SimLMConfig):
    """SimLM forward: (B, S) int32 ids + (B, S) mask -> (B, S, D) unit-norm
    token embeddings (pre-pooling)."""
    x = params["tok_embed"][ids] + params["pos_embed"][None, :, :]
    for layer in range(cfg.n_layers):
        p = f"layer{layer}."
        h = _layer_norm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        x = x + _attention(
            h,
            mask,
            params[p + "wq"],
            params[p + "wk"],
            params[p + "wv"],
            params[p + "wo"],
            cfg.n_heads,
        )
        h = _layer_norm(x, params[p + "ln2_scale"], params[p + "ln2_bias"])
        h = jax.nn.gelu(h @ params[p + "w1"] + params[p + "b1"])
        x = x + h @ params[p + "w2"] + params[p + "b2"]
    x = _layer_norm(x, params["lnf_scale"], params["lnf_bias"])
    # Unit-normalise token embeddings so dot product == cosine similarity.
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-8)


def embed_fn(params, ids, mask, cfg: SimLMConfig):
    """Pooled sentence embedding: masked mean of token embeddings, L2-norm."""
    tok = encode_tokens(params, ids, mask, cfg)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(tok * mask[:, :, None], axis=1) / denom
    pooled = pooled / jnp.maximum(
        jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-8
    )
    return (pooled,)


def bertscore_fn(params, ids_a, mask_a, ids_b, mask_b, cfg: SimLMConfig):
    """Encode both sides with shared weights, then the L1 Pallas kernel."""
    tok_a = encode_tokens(params, ids_a, mask_a, cfg)
    tok_b = encode_tokens(params, ids_b, mask_b, cfg)
    p, r, f1 = bertscore_prf(
        tok_a,
        tok_b,
        mask_a,
        mask_b,
        tile_m=cfg.kernel_tile_m,
        tile_n=cfg.kernel_tile_n,
    )
    return (p, r, f1)


@dataclass(frozen=True)
class BootstrapConfig:
    """Fixed AOT shapes for the bootstrap-resample graph."""

    resamples: int = 1000   # paper default bootstrap_iterations
    max_n: int = 1024       # values padded/masked up to this length


def bootstrap_fn(values, idx, mask):
    """(R,) masked means of gathered resamples — see ref.bootstrap_means_ref."""
    gathered = jnp.take(values, idx, axis=0)
    means = jnp.sum(gathered * mask, axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1), 1.0
    )
    return (means,)
